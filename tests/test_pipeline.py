"""Data Pipeline (§V): incremental O(1) updates, window table, archive."""

import numpy as np

from repro.core import FeatureProcessor, compute_features


def test_pipeline_matches_batch_replay():
    rng = np.random.default_rng(0)
    pools = ["a", "b", "c"]
    t_max, n = 60, 10
    s = rng.integers(0, n + 1, size=(len(pools), t_max))

    proc = FeatureProcessor(pools, n_requests=n, window_minutes=30, dt_minutes=3)
    streamed = np.zeros((len(pools), t_max, 3))
    for t in range(t_max):
        rows = proc.on_cycle(t, t * 180.0, s[:, t])
        for i, pid in enumerate(pools):
            streamed[i, t] = rows[pid].features

    batch = compute_features(s, n, 30, 3)
    np.testing.assert_allclose(streamed, batch, atol=1e-12)


def test_window_table_bounded_and_archive_grows():
    pools = ["a"]
    proc = FeatureProcessor(pools, n_requests=10, window_minutes=30, dt_minutes=3)
    w = proc.window_cycles
    for t in range(3 * w):
        proc.on_cycle(t, t * 180.0, [10])
    assert len(proc.table.rows["a"]) == w          # bounded by the window
    assert len(proc.table.archive) == 2 * w        # evictions archived


def test_update_work_is_constant_per_cycle():
    """O(1) incremental property: state-update count is pools x cycles,
    independent of history length (Algorithm 1's point)."""
    pools = [f"p{i}" for i in range(5)]
    proc = FeatureProcessor(pools, n_requests=10, window_minutes=60, dt_minutes=3)
    for t in range(100):
        proc.on_cycle(t, t * 180.0, [10] * 5)
    assert proc.update_ops == 5 * 100


def test_predictions_attached_to_rows():
    proc = FeatureProcessor(
        ["a"], n_requests=10, window_minutes=30, dt_minutes=3,
        predict_fn=lambda feats: float(feats[0] > 0.5),
    )
    rows = proc.on_cycle(0, 0.0, [10])
    assert rows["a"].prediction == 1.0
    rows = proc.on_cycle(1, 180.0, [0])
    assert rows["a"].prediction == 0.0
