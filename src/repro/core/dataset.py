"""Dataset construction: SnS traces → (features, labels) — paper §VI-A.

Features are computed from the SnS probe trace (:mod:`.features`), labels
from the simultaneously collected running-instance trace (:mod:`.labels`).
Two split protocols, both from the paper:

* ``split="random"`` — 75/25 random point split with a fixed seed (§VI-A,
  used for the prediction experiments of Figs. 7-8).
* ``split="pool"`` — 75/25 split at the *instance-type level* so no
  evaluation pool's trace is seen in training (§VI-E, used for the
  trace-driven simulation).

Point-wise models receive ``X[t] = (SR_t, UR_t, CUT_t)`` (or a feature
subset, Fig. 8); sequence models receive the trailing ``L`` cycles of the
same features, ``X[t] = F[t-L+1 : t+1]``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .collector import CampaignResult
from .features import FEATURE_NAMES, compute_features
from .labels import HorizonLabelStream, binary_availability, horizon_labels

__all__ = ["Dataset", "Standardizer", "build_dataset", "DatasetStreamer"]


@dataclasses.dataclass
class Standardizer:
    mean: np.ndarray
    std: np.ndarray

    @classmethod
    def fit(cls, x: np.ndarray) -> "Standardizer":
        flat = x.reshape(-1, x.shape[-1])
        std = flat.std(axis=0)
        std = np.where(std < 1e-8, 1.0, std)
        return cls(mean=flat.mean(axis=0), std=std)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        return (x - self.mean) / self.std


@dataclasses.dataclass
class Dataset:
    """Train/test split of SnS features and availability labels."""

    x_train: np.ndarray     # (n, F) or (n, L, F) for sequence models
    y_train: np.ndarray     # (n,)
    x_test: np.ndarray
    y_test: np.ndarray
    feature_names: Tuple[str, ...]
    horizon_cycles: int
    # bookkeeping for the trace-driven simulator (§VI-E)
    train_pools: Optional[np.ndarray] = None
    test_pools: Optional[np.ndarray] = None
    standardizer: Optional[Standardizer] = None


def _select_features(feats: np.ndarray, names: Sequence[str]) -> np.ndarray:
    idx = [FEATURE_NAMES.index(n) for n in names]
    return feats[..., idx]


def build_dataset(
    result: CampaignResult,
    *,
    window_minutes: float = 480.0,
    horizon_minutes: float = 0.0,
    feature_set: Sequence[str] = FEATURE_NAMES,
    sequence_length: Optional[int] = None,
    split: str = "random",
    train_fraction: float = 0.75,
    seed: int = 0,
    standardize: bool = True,
) -> Dataset:
    """Build a supervised dataset from a measurement campaign."""
    dt_minutes = result.interval / 60.0
    h = int(round(horizon_minutes / dt_minutes))

    feats = compute_features(result.s, result.n, window_minutes, dt_minutes)
    avail = binary_availability(result.running, result.n)  # (pools, T)
    y = horizon_labels(avail, h)                           # (pools, T - h)
    return _assemble_dataset(
        feats,
        y,
        h,
        feature_set=feature_set,
        sequence_length=sequence_length,
        split=split,
        train_fraction=train_fraction,
        seed=seed,
        standardize=standardize,
    )


def _assemble_dataset(
    feats: np.ndarray,
    y: np.ndarray,
    h: int,
    *,
    feature_set: Sequence[str] = FEATURE_NAMES,
    sequence_length: Optional[int] = None,
    split: str = "random",
    train_fraction: float = 0.75,
    seed: int = 0,
    standardize: bool = True,
) -> Dataset:
    """Point/sequence extraction + split + standardization over prepared
    ``(pools, T, F)`` features and ``(pools, T - h)`` labels — shared by
    the offline :func:`build_dataset` and the streaming
    :class:`DatasetStreamer` so their outputs cannot diverge."""
    feats = _select_features(feats, feature_set)          # (pools, T, F)
    pools, t_total, n_feat = feats.shape
    t_lab = y.shape[-1]

    if sequence_length is None:
        # one point per (pool, cycle)
        x = feats[:, :t_lab, :]                            # (pools, T-h, F)
        start = 0
    else:
        # trailing L-cycle windows; first valid cycle index is L-1
        lseq = int(sequence_length)
        if lseq > t_lab:
            raise ValueError(f"sequence_length {lseq} > usable length {t_lab}")
        windows = np.stack(
            [feats[:, k : t_lab - lseq + 1 + k, :] for k in range(lseq)], axis=2
        )                                                   # (pools, T', L, F)
        x = windows
        start = lseq - 1
        y = y[:, start:]

    pool_idx = np.broadcast_to(
        np.arange(pools)[:, None], y.shape
    )

    if split == "random":
        rng = np.random.default_rng(seed)
        flat_x = x.reshape((-1,) + x.shape[2:])
        flat_y = y.reshape(-1)
        flat_p = pool_idx.reshape(-1)
        perm = rng.permutation(flat_y.shape[0])
        cut = int(train_fraction * len(perm))
        tr, te = perm[:cut], perm[cut:]
        xtr, ytr, xte, yte = flat_x[tr], flat_y[tr], flat_x[te], flat_y[te]
        ptr, pte = flat_p[tr], flat_p[te]
    elif split == "pool":
        rng = np.random.default_rng(seed)
        order = rng.permutation(pools)
        cut = max(1, int(train_fraction * pools))
        train_pools, test_pools = order[:cut], order[cut:]
        xtr = x[train_pools].reshape((-1,) + x.shape[2:])
        ytr = y[train_pools].reshape(-1)
        xte = x[test_pools].reshape((-1,) + x.shape[2:])
        yte = y[test_pools].reshape(-1)
        ptr = np.repeat(train_pools, y.shape[1])
        pte = np.repeat(test_pools, y.shape[1])
    else:
        raise ValueError(f"unknown split {split!r}")

    std = None
    if standardize:
        std = Standardizer.fit(xtr)
        xtr, xte = std(xtr), std(xte)

    return Dataset(
        x_train=xtr.astype(np.float32),
        y_train=ytr.astype(np.int32),
        x_test=xte.astype(np.float32),
        y_test=yte.astype(np.int32),
        feature_names=tuple(feature_set),
        horizon_cycles=h,
        train_pools=ptr,
        test_pools=pte,
        standardizer=std,
    )


class DatasetStreamer:
    """Multi-horizon ``(X, y)`` accumulation streamed from a live campaign.

    The streaming counterpart of :func:`build_dataset`: instead of
    replaying the finished campaign's ``S`` matrix through
    ``compute_features``, it consumes each cycle as it lands in the
    campaign pipeline — the per-cycle ``(pools, F)`` feature row from the
    :class:`~repro.core.pipeline.FleetWindowTable` ring (grabbed at append
    time, so the window table can evict freely) and the ground-truth
    ``running_t`` column.  Labels are built **incrementally** through one
    :class:`~repro.core.labels.HorizonLabelStream` per requested horizon:
    a label is emitted the moment its future window closes, so no
    availability trace is ever materialized.

    Feed it :class:`~repro.core.pipeline.StreamCycleView` objects via
    :meth:`ingest` (or raw columns via :meth:`on_cycle`); at any point —
    including mid-campaign — :meth:`matrices` / :meth:`dataset` assemble
    the supervised data collected so far.  :meth:`dataset` routes through
    the same assembly code as :func:`build_dataset`, and the streamed
    features/labels are bit-identical to the offline replay of the final
    ``S`` / ``running`` matrices, so for a fully consumed campaign

        ``streamer.dataset(h, ...) == build_dataset(result, ...)``

    field for field at atol=0, on every campaign engine
    (``tests/test_labels_dataset.py``).

    Args:
      n: requested pool size (the campaign's ``n_requests`` — the
        availability threshold of §IV-A).
      horizons_cycles: the prediction horizons, in collection cycles
        (``horizon_minutes / dt``); ``0`` = current-availability labels.
    """

    def __init__(self, n: int, horizons_cycles: Sequence[int]):
        self.n = int(n)
        horizons = [int(h) for h in horizons_cycles]
        if len(set(horizons)) != len(horizons):
            raise ValueError(f"duplicate horizons in {horizons}")
        self.horizons = tuple(horizons)
        self._labelers = {h: HorizonLabelStream(h) for h in self.horizons}
        self._feat_cols: list = []                    # per-cycle (pools, F)
        self._label_cols = {h: [] for h in self.horizons}
        self.cycles = 0

    def on_cycle(
        self, cycle: int, features: np.ndarray, running_t: np.ndarray
    ) -> None:
        """Ingest one cycle's feature row + ground-truth running counts."""
        if cycle != self.cycles:
            raise ValueError(
                f"cycle {cycle} out of order: streamer is at {self.cycles} "
                "(cycles must arrive contiguously from 0)"
            )
        # copy: `features` is typically a ring-slot view that the window
        # table will overwrite once the ring wraps
        self._feat_cols.append(np.array(features, dtype=np.float64))
        avail_t = binary_availability(np.asarray(running_t), self.n)
        for h, labeler in self._labelers.items():
            y_col = labeler.push(avail_t)
            if y_col is not None:
                self._label_cols[h].append(y_col)
        self.cycles += 1

    def ingest(self, view) -> None:
        """Ingest a :class:`~repro.core.pipeline.StreamCycleView`."""
        self.on_cycle(view.cycle, view.features, view.running_t)

    # -- assembly ------------------------------------------------------------

    def features(self) -> np.ndarray:
        """All streamed features so far, ``(pools, T, F)``."""
        if not self._feat_cols:
            raise ValueError("no cycles streamed yet")
        return np.stack(self._feat_cols, axis=1)

    def labels(self, horizon_cycles: int) -> np.ndarray:
        """Finalized labels for one horizon so far, ``(pools, T - h)`` —
        bit-identical to ``horizon_labels(avail, h)`` on the trace."""
        h = int(horizon_cycles)
        if h not in self._labelers:
            raise ValueError(f"horizon {h} not tracked (have {self.horizons})")
        cols = self._label_cols[h]
        if not cols:
            raise ValueError(
                f"horizon {h} >= streamed length {self.cycles}: no label "
                "window has closed yet"
            )
        return np.stack(cols, axis=1)

    def matrices(self, horizon_cycles: int):
        """Aligned point-wise ``(X, y)``: ``(pools, T - h, F)`` features and
        ``(pools, T - h)`` labels, unsplit and unstandardized."""
        y = self.labels(horizon_cycles)
        x = self.features()[:, : y.shape[1], :]
        return x, y

    def dataset(
        self,
        horizon_cycles: int,
        *,
        feature_set: Sequence[str] = FEATURE_NAMES,
        sequence_length: Optional[int] = None,
        split: str = "random",
        train_fraction: float = 0.75,
        seed: int = 0,
        standardize: bool = True,
    ) -> Dataset:
        """Assemble a :class:`Dataset` from the cycles streamed so far —
        for a fully consumed campaign, bit-identical to
        :func:`build_dataset` with ``horizon_minutes = h * dt``."""
        h = int(horizon_cycles)
        return _assemble_dataset(
            self.features(),
            self.labels(h),
            h,
            feature_set=feature_set,
            sequence_length=sequence_length,
            split=split,
            train_fraction=train_fraction,
            seed=seed,
            standardize=standardize,
        )
