"""Shared mini-batch Adam training loop for the gradient-based predictors.

All predictors are small (the paper's point: feature design beats model
complexity), so a plain jit-compiled epoch scan over shuffled mini-batches
is fast even on one CPU core.  Class imbalance is handled with inverse-
frequency sample weights, which matters because unavailable cycles are the
minority class and the evaluation metric is F1-macro.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LossFn = Callable[..., jnp.ndarray]  # (params, x, y, w) -> scalar


def class_weights(y: np.ndarray) -> np.ndarray:
    """Inverse-frequency weights, normalised to mean 1."""
    y = np.asarray(y)
    pos = max(1, int(y.sum()))
    neg = max(1, int((1 - y).sum()))
    n = len(y)
    w = np.where(y == 1, n / (2.0 * pos), n / (2.0 * neg))
    return (w / w.mean()).astype(np.float32)


@partial(jax.jit, static_argnames=("loss_fn", "steps", "batch", "lr"))
def _fit_jit(params, x, y, w, key, *, loss_fn: LossFn, steps: int, batch: int, lr: float):
    """Adam over `steps` mini-batches sampled with replacement."""
    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    b1, b2, eps = 0.9, 0.999, 1e-8
    n = x.shape[0]

    def step(carry, i):
        params, m, v, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, n)
        xb, yb, wb = x[idx], y[idx], w[idx]
        grads = jax.grad(loss_fn)(jax.tree_util.tree_unflatten(tree, params), xb, yb, wb)
        gflat, _ = jax.tree_util.tree_flatten(grads)
        t = i + 1.0
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, gflat, m, v):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**t)
            vhat = vi / (1 - b2**t)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return (new_p, new_m, new_v, key), 0.0

    (flat, _, _, _), _ = jax.lax.scan(step, (flat, m, v, key), jnp.arange(float(steps)))
    return jax.tree_util.tree_unflatten(tree, flat)


def fit_adam(
    params,
    loss_fn: LossFn,
    x: np.ndarray,
    y: np.ndarray,
    *,
    steps: int = 400,
    batch: int = 1024,
    lr: float = 1e-2,
    seed: int = 0,
) -> Tuple:
    """numpy-in, params-out wrapper around the jitted loop."""
    w = class_weights(y)
    batch = int(min(batch, len(y)))
    return _fit_jit(
        params,
        jnp.asarray(x),
        jnp.asarray(y, dtype=jnp.float32),
        jnp.asarray(w),
        jax.random.PRNGKey(seed),
        loss_fn=loss_fn,
        steps=steps,
        batch=batch,
        lr=lr,
    )
