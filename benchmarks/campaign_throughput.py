"""Campaign engine throughput — pool-cycles/sec, scalar vs fleet vs sharded.

Measures a full measure→record campaign (`repro.core.run_campaign`:
regime dynamics + node pools + SnS probing) through the three collector
engines on the same fleet:

1. ``scalar``  — the paper-faithful per-pool path: one
   ``submit_spot_request`` per pool per cycle, per-request
   ``SpotRequest`` objects, per-probe Data-Lake rows (hot-path record
   retention off, the fair configuration at this scale);
2. ``fleet``   — the batched numpy engine: one ``submit_spot_requests``
   admission call per cycle for the whole fleet, matrices in place of
   objects;
3. ``sharded`` — the mesh-sharded JAX engine (`repro.core.sharded`):
   pool state device-sharded over a 1-D ``("pools",)`` mesh, one
   ``shard_map``-ped jitted step per cycle.  Measured after a short
   warm-up campaign so the one-time XLA compile (cached process-wide
   across campaigns) is excluded — the steady-state rate is what a
   long campaign sees.

Because all engines ride the provider's counter-based per-pool RNG
streams, the benchmark also *asserts* the parity anchor: identical
``S_t`` / ``running_t`` matrices and interruption event logs from all
three engines.

Usage:
    PYTHONPATH=src python benchmarks/campaign_throughput.py [--smoke]
        [--pools 4096] [--cycles 16] [--engine all|scalar|fleet|sharded]

The full run asserts (at 4096 pools x 16 cycles on CPU) that the fleet
engine clears >= 20x the scalar engine and the sharded engine >= 1x the
fleet engine on a single device, and appends a perf record (with the
device count, so multi-device trajectories accumulate in the same file)
to ``BENCH_campaign.json``.  ``--smoke`` only checks plumbing + parity.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

N_REQ = 10
INTERVAL = 180.0
REQUIRED_SPEEDUP = 20.0           # fleet vs scalar
REQUIRED_SHARDED_SPEEDUP = 1.0    # sharded vs fleet, 1-device CPU floor
ENGINES = ("scalar", "fleet", "sharded")


def _provider(pools: int, seed: int = 0):
    from repro.core import SimulatedProvider, default_fleet

    # rate limits sized for the paper's 68-pool campaign would starve a
    # SpotLake-class fleet; lift them so all engines probe every pool
    return SimulatedProvider(
        default_fleet(pools, seed=seed),
        seed=seed + 1,
        requests_per_minute_per_region=10**9,
    )


def bench_engine(engine: str, pools: int, cycles: int) -> float:
    """pool-cycles/sec for one engine (fresh provider, same seed)."""
    from repro.core import run_campaign

    if engine == "sharded":
        # warm the process-wide compiled-step cache (one short campaign);
        # steady-state throughput is the quantity that scales with fleets
        run_campaign(
            _provider(pools),
            duration=2 * INTERVAL,
            interval=INTERVAL,
            n_requests=N_REQ,
            engine=engine,
        )
    provider = _provider(pools)
    t0 = time.perf_counter()
    run_campaign(
        provider,
        duration=cycles * INTERVAL,
        interval=INTERVAL,
        n_requests=N_REQ,
        engine=engine,
        retain_records=False,
    )
    return pools * cycles / (time.perf_counter() - t0)


def check_parity(pools: int = 256, cycles: int = 8) -> bool:
    """All engines bit-for-bit identical on shared RNG streams."""
    from repro.core import run_campaign

    results = {}
    for engine in ENGINES:
        results[engine] = run_campaign(
            _provider(pools, seed=3),
            duration=cycles * INTERVAL,
            interval=INTERVAL,
            n_requests=N_REQ,
            engine=engine,
            retain_records=False,
        )
    ref = results["scalar"]
    for engine in ("fleet", "sharded"):
        got = results[engine]
        np.testing.assert_array_equal(ref.s, got.s)
        np.testing.assert_array_equal(ref.running, got.running)
        assert ref.interruptions == got.interruptions, (
            f"interruption logs diverged: scalar vs {engine}"
        )
        assert ref.api_calls == got.api_calls
    return True


def run(
    pools: int = 4096, cycles: int = 16, smoke: bool = False, engine: str = "all"
) -> dict:
    import jax

    engines = ENGINES if engine == "all" else (engine,)
    if smoke:
        pools, cycles = min(pools, 256), min(cycles, 8)
    sizes = sorted({min(1024, pools), pools})

    per_size = {}
    for p in sizes:
        rates = {e: bench_engine(e, p, cycles) for e in engines}
        entry = {"pool_cycles_per_sec": {e: round(r) for e, r in rates.items()}}
        if "scalar" in rates and "fleet" in rates:
            entry["speedup"] = round(rates["fleet"] / rates["scalar"], 1)
        if "fleet" in rates and "sharded" in rates:
            entry["speedup_sharded_vs_fleet"] = round(
                rates["sharded"] / rates["fleet"], 2
            )
        per_size[p] = entry

    result = {
        "cycles": cycles,
        "devices": len(jax.devices()),
        "per_pools": per_size,
        "parity_identical": check_parity(
            pools=min(pools, 256), cycles=min(cycles, 8)
        ),
        "smoke": smoke,
    }
    top = per_size[pools]
    if "speedup" in top:
        result["speedup"] = top["speedup"]
    if "speedup_sharded_vs_fleet" in top:
        result["speedup_sharded_vs_fleet"] = top["speedup_sharded_vs_fleet"]
    if not smoke:
        if "speedup" in result:
            assert result["speedup"] >= REQUIRED_SPEEDUP, result
        if "speedup_sharded_vs_fleet" in result:
            assert (
                result["speedup_sharded_vs_fleet"] >= REQUIRED_SHARDED_SPEEDUP
            ), result
        rec = dict(result, timestamp=time.strftime("%Y-%m-%dT%H:%M:%S"))
        with open(Path.cwd() / "BENCH_campaign.json", "a") as f:
            f.write(json.dumps(rec) + "\n")
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pools", type=int, default=4096)
    ap.add_argument("--cycles", type=int, default=16)
    ap.add_argument("--engine", choices=("all",) + ENGINES, default="all",
                    help="bench one engine only (parity always checks all)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; skip the speedup assertions")
    args = ap.parse_args()
    result = run(
        pools=args.pools, cycles=args.cycles, smoke=args.smoke,
        engine=args.engine,
    )
    print(json.dumps(result, indent=1))


if __name__ == "__main__":
    main()
