from .checkpoint import latest_step, list_steps, load_checkpoint, save_checkpoint
from .optim import OptConfig, apply_updates, init_opt_state, schedule
from .trainer import make_eval_step, make_train_step, synthetic_batch

__all__ = [
    "latest_step", "list_steps", "load_checkpoint", "save_checkpoint",
    "OptConfig", "apply_updates", "init_opt_state", "schedule",
    "make_eval_step", "make_train_step", "synthetic_batch",
]
