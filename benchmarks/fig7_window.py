"""Fig. 7: current-availability F1-macro vs feature window size."""

from __future__ import annotations

from repro.core import build_dataset, evaluate, fit_predictor

from .common import paper_campaign

# paper: RF/XGB/Transformer improve then stabilise ~480 min; LSTM peaks at
# 120 min; LR/SVM flat.
WINDOWS_MIN = (60, 120, 240, 480, 720)
MODELS = ("lr", "xgb", "rf")               # fast set; sequence models in fig8
SEQ_MODELS = ()


def run(windows=WINDOWS_MIN, models=MODELS):
    c = paper_campaign()
    out = {}
    for w in windows:
        ds = build_dataset(c, window_minutes=w, horizon_minutes=0, seed=0)
        row = {}
        for m in models:
            model = fit_predictor(m, ds)
            row[m] = round(evaluate(model, ds)["f1_macro"], 3)
        out[f"{w}min"] = row
    best = {
        m: max(out[f"{w}min"][m] for w in windows) for m in models
    }
    return {"f1_by_window": out, "best_per_model": best,
            "paper": "best ~0.90 (RF/XGB), stabilising beyond ~480 min"}


if __name__ == "__main__":
    print(run())
