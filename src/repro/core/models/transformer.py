"""Tiny Transformer sequence predictor — paper §VI-A sequence model group.

Two pre-norm encoder blocks over the trailing feature window, sinusoidal
positions, mean pooling, linear head.  Deliberately small: the paper's
finding is that feature design dominates model complexity for this task.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ._train import fit_adam

__all__ = ["TransformerClassifier"]


def _sincos(l: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(l)[:, None]
    i = jnp.arange(d // 2)[None, :]
    angle = pos / (10000 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _init(key, n_in: int, d: int, n_layers: int) -> Dict:
    keys = jax.random.split(key, 1 + 4 * n_layers)
    params: Dict = {
        "embed": jax.random.normal(keys[0], (n_in, d)) * (1.0 / n_in) ** 0.5,
        "head_w": jnp.zeros((d, 1)),
        "head_b": jnp.zeros((1,)),
        "blocks": [],
    }
    s = (1.0 / d) ** 0.5
    for li in range(n_layers):
        k = keys[1 + 4 * li : 5 + 4 * li]
        params["blocks"].append(
            {
                "wqkv": jax.random.normal(k[0], (d, 3 * d)) * s,
                "wo": jax.random.normal(k[1], (d, d)) * s,
                "w1": jax.random.normal(k[2], (d, 4 * d)) * s,
                "w2": jax.random.normal(k[3], (4 * d, d)) * (1.0 / (4 * d)) ** 0.5,
                "ln1": jnp.ones((d,)),
                "ln2": jnp.ones((d,)),
            }
        )
    return params


def _ln(x, g):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-6)


def _forward(params, x, *, n_heads: int = 2):
    b, l, _ = x.shape
    h = x @ params["embed"]
    d = h.shape[-1]
    h = h + _sincos(l, d)[None]
    hd = d // n_heads
    for blk in params["blocks"]:
        y = _ln(h, blk["ln1"])
        qkv = y @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)
        att = jax.nn.softmax(q @ jnp.swapaxes(k, -1, -2) / hd**0.5, axis=-1)
        y = (att @ v).transpose(0, 2, 1, 3).reshape(b, l, d) @ blk["wo"]
        h = h + y
        y = _ln(h, blk["ln2"])
        h = h + jax.nn.gelu(y @ blk["w1"]) @ blk["w2"]
    pooled = h.mean(axis=1)
    return (pooled @ params["head_w"] + params["head_b"])[..., 0]


@dataclasses.dataclass
class TransformerClassifier:
    d_model: int = 32
    n_layers: int = 2
    n_heads: int = 2
    steps: int = 500
    batch: int = 256
    lr: float = 1e-3
    seed: int = 0
    params: Dict = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "TransformerClassifier":
        assert x.ndim == 3, "Transformer expects (N, L, F) sequences"
        n_heads = self.n_heads

        def loss(params, xb, yb, wb):
            logits = _forward(params, xb, n_heads=n_heads)
            return (wb * (jax.nn.softplus(logits) - yb * logits)).mean()

        init = _init(
            jax.random.PRNGKey(self.seed), x.shape[-1], self.d_model, self.n_layers
        )
        self.params = fit_adam(
            init, loss, x, y,
            steps=self.steps, batch=self.batch, lr=self.lr, seed=self.seed,
        )
        return self

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        probs = []
        for i in range(0, len(x), 4096):  # bounded memory at predict time
            logits = _forward(
                self.params, jnp.asarray(x[i : i + 4096]), n_heads=self.n_heads
            )
            probs.append(np.asarray(jax.nn.sigmoid(logits)))
        return np.concatenate(probs)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)
