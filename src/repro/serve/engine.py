"""Serving engine: batched prefill + decode with SnS-aware admission.

``generate`` is the plain engine (prefill once, decode N tokens).
``AdmissionController`` applies the paper's Predict-AR policy to serving:
consult the SnS predictor each collection cycle; when it forecasts that
the pool will not stay available over the horizon, *defer admitting new
requests* (drain-friendly) while letting in-flight decodes finish — the
same leave-running-work-undisturbed semantics as §VI-E.  ``plan_migration``
picks the healthiest alternative pool by current SnS features (SpotServe-
style proactive migration, reduced to its scheduling decision).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig

__all__ = ["generate", "AdmissionController", "plan_migration"]


def generate(
    cfg: ModelConfig,
    params,
    batch: Dict,
    *,
    max_new_tokens: int = 16,
    mesh=None,
    data_axes=("data",),
    greedy: bool = True,
    seed: int = 0,
) -> jnp.ndarray:
    """Prefill + decode loop; returns (B, max_new_tokens) generated ids."""
    b, s = batch["tokens"].shape
    logits, cache = api.prefill(
        cfg, params, batch, mesh=mesh, data_axes=data_axes,
        max_seq=s + max_new_tokens,
    )
    key = jax.random.PRNGKey(seed)
    outs = []
    tok = None
    for i in range(max_new_tokens):
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        outs.append(tok)
        if i + 1 < max_new_tokens:
            logits, cache = api.decode_step(
                cfg, params, cache, tok, mesh=mesh, data_axes=data_axes
            )
    return jnp.stack(outs, axis=1)


@dataclasses.dataclass
class AdmissionController:
    """Predict-AR for serving admission (one controller per pool)."""

    predictor: Callable[[np.ndarray], float]   # features -> P(stays available)
    horizon_cycles: int = 5
    threshold: float = 0.5
    _defer_until: int = -1

    def on_cycle(self, cycle: int, features: np.ndarray) -> bool:
        """Returns True if NEW requests may be admitted this cycle."""
        if cycle <= self._defer_until:
            return False
        p_stay = float(self.predictor(features))
        if 1.0 - p_stay >= self.threshold:
            self._defer_until = cycle + self.horizon_cycles
            return False
        return True


def plan_migration(
    pool_features: Dict[str, np.ndarray],
    predictor: Callable[[np.ndarray], float],
    *,
    current: str,
) -> Optional[str]:
    """Pick the best migration target when `current` looks unhealthy.

    Returns None if `current` still scores best (no migration)."""
    scores = {pid: float(predictor(f)) for pid, f in pool_features.items()}
    best = max(scores, key=scores.get)
    if best == current or scores[best] <= scores[current] + 1e-9:
        return None
    return best
