"""The streaming serve path: CampaignStream / CampaignPipelineStream
bit-identity with the batch drivers, the fleet-vectorised Predict-AR
decision layer, and the deterministic migration tie-break."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CampaignPipelineStream,
    CampaignStream,
    SimulatedProvider,
    default_fleet,
    run_campaign,
    run_campaign_pipeline,
)
from repro.serve import (
    AdmissionController,
    FleetAdmissionController,
    plan_migration,
    plan_migration_batch,
)

ENGINES = ("scalar", "fleet", "sharded")


def fresh(n_pools=10, seed=11, **kw):
    return SimulatedProvider(default_fleet(n_pools, seed=seed), seed=seed + 1, **kw)


class TestCampaignStream:
    """run_campaign is a thin driver over CampaignStream — the streamed
    and batch paths must be bit-identical on every engine."""

    @pytest.mark.parametrize("engine", ENGINES)
    def test_stream_equals_batch(self, engine):
        batch = run_campaign(fresh(), duration=2 * 3600.0, engine=engine)
        stream = CampaignStream(fresh(), duration=2 * 3600.0, engine=engine)
        cycles = list(stream)
        got = stream.result()
        assert len(cycles) == stream.n_cycles == batch.s.shape[1]
        np.testing.assert_array_equal(batch.s, got.s)
        np.testing.assert_array_equal(batch.running, got.running)
        np.testing.assert_array_equal(batch.times, got.times)
        assert batch.interruptions == got.interruptions
        assert batch.api_calls == got.api_calls
        assert batch.probe_compute_cost == got.probe_compute_cost
        assert batch.node_pool_cost == got.node_pool_cost
        assert got.engine == engine

    def test_cycle_views_alias_matrices(self):
        stream = CampaignStream(fresh(4), duration=1800.0)
        cyc = stream.step()
        # zero-copy contract: per-cycle columns are views, not copies
        assert np.shares_memory(cyc.s_t, stream.s)
        assert np.shares_memory(cyc.running_t, stream.running)
        np.testing.assert_array_equal(cyc.s_t, stream.s[:, 0])
        # ...but read-only: a mutating on_cycle hook must not be able to
        # corrupt the eventual CampaignResult matrices through them
        with pytest.raises(ValueError):
            cyc.s_t[0] = 99
        with pytest.raises(ValueError):
            cyc.running_t[0] = 99
        assert stream.s.flags.writeable  # the stream itself still writes

    def test_resumable_and_exhaustion(self):
        stream = CampaignStream(fresh(4), duration=3600.0)
        n = stream.n_cycles
        first = [stream.step() for _ in range(2)]  # pause after 2 cycles...
        assert [c.cycle for c in first] == [0, 1]
        assert stream.cycles_done == 2 and not stream.done
        with pytest.raises(RuntimeError):
            stream.result()  # partial stream has no CampaignResult yet
        rest = list(stream)  # ...then resume to exhaustion
        assert [c.cycle for c in rest] == list(range(2, n))
        assert stream.done and stream.step() is None
        assert stream.result().s.shape == (4, n)

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError):
            CampaignStream(fresh(3), engine="warp")

    def test_sharded_terminator_delay_streams(self):
        # slow terminators are now first-class on the sharded engine:
        # streamed sharded ≡ streamed fleet, leaks included
        kw = dict(duration=2 * 3600.0, terminator_delay=30.0)
        fleet = CampaignStream(fresh(3), engine="fleet", **kw)
        sharded = CampaignStream(fresh(3), engine="sharded", **kw)
        list(fleet), list(sharded)
        a, b = fleet.result(), sharded.result()
        np.testing.assert_array_equal(a.s, b.s)
        np.testing.assert_array_equal(a.running, b.running)
        assert a.interruptions == b.interruptions


class TestCampaignPipelineStream:
    """Streamed measure→featurize→predict ≡ run_campaign_pipeline."""

    @pytest.mark.parametrize("engine", ENGINES)
    def test_stream_equals_batch_pipeline(self, engine):
        kw = dict(
            duration=2 * 3600.0,
            predict_fn=lambda x: x[:, 0],
            window_minutes=30.0,
            engine=engine,
        )
        batch_result, batch_proc = run_campaign_pipeline(fresh(6, 17), **kw)
        stream = CampaignPipelineStream(fresh(6, 17), **kw)
        seen = 0
        for view in stream:
            assert view.probs is not None
            seen += 1
        result, proc = stream.result(), stream.processor
        assert seen == stream.n_cycles
        np.testing.assert_array_equal(batch_result.s, result.s)
        np.testing.assert_array_equal(batch_result.running, result.running)
        assert batch_result.interruptions == result.interruptions
        np.testing.assert_array_equal(
            batch_proc.table.features, proc.table.features
        )
        np.testing.assert_array_equal(
            batch_proc.table.predictions, proc.table.predictions
        )
        assert proc.update_ops == proc.predict_calls == stream.n_cycles

    def test_views_are_ring_slots(self):
        stream = CampaignPipelineStream(
            fresh(5), duration=1800.0, predict_fn=lambda x: x[:, 0],
            window_minutes=30.0,
        )
        view = stream.step()
        table = stream.processor.table
        assert np.shares_memory(view.features, table.features)
        assert np.shares_memory(view.probs, table.predictions)
        np.testing.assert_array_equal(view.features, table.features[:, table.head])
        with pytest.raises(ValueError):  # ring-slot views are read-only
            view.features[0, 0] = 99.0
        assert table.features.flags.writeable  # the ring itself still writes

    def test_run_drains_remaining(self):
        kw = dict(duration=3600.0, window_minutes=30.0)
        stream = CampaignPipelineStream(fresh(4, 23), **kw)
        stream.step()  # consume one cycle by hand, then hand off
        result, proc = stream.run()
        want, _ = run_campaign_pipeline(fresh(4, 23), **kw)
        np.testing.assert_array_equal(want.s, result.s)
        assert proc.update_ops == result.s.shape[1]

    def test_no_predictor_yields_none_probs(self):
        stream = CampaignPipelineStream(fresh(3), duration=1800.0)
        view = stream.step()
        assert view.probs is None and view.features.shape == (3, 3)


class TestFleetAdmission:
    """A loop of scalar AdmissionControllers ≡ one FleetAdmissionController
    — decisions AND defer clocks, cycle for cycle."""

    @staticmethod
    def _compare(probs, thresholds, horizons):
        cycles, pools = probs.shape
        ctls = [
            AdmissionController(
                predictor=lambda f: float(f[0]),
                horizon_cycles=int(horizons[p]),
                threshold=float(thresholds[p]),
            )
            for p in range(pools)
        ]
        fleet = FleetAdmissionController(
            pools, horizon_cycles=horizons, threshold=thresholds
        )
        for c in range(cycles):
            want = np.array(
                [ctls[p].on_cycle(c, probs[c, p : p + 1]) for p in range(pools)]
            )
            got = fleet.on_cycle(c, probs[c])
            np.testing.assert_array_equal(want, got)
            np.testing.assert_array_equal(
                np.array([ctl._defer_until for ctl in ctls]), fleet.defer_until
            )

    @given(
        seed=st.integers(0, 10_000),
        pools=st.integers(1, 8),
        cycles=st.integers(1, 40),
        threshold=st.floats(0.05, 0.95),
        horizon=st.integers(1, 8),
    )
    @settings(max_examples=25, deadline=None)
    def test_scalar_loop_equals_fleet(self, seed, pools, cycles, threshold, horizon):
        rng = np.random.default_rng(seed)
        probs = rng.random((cycles, pools))
        self._compare(
            probs,
            np.full(pools, threshold),
            np.full(pools, horizon, dtype=np.int64),
        )

    @given(seed=st.integers(0, 10_000), pools=st.integers(2, 8))
    @settings(max_examples=15, deadline=None)
    def test_heterogeneous_thresholds_and_horizons(self, seed, pools):
        rng = np.random.default_rng(seed)
        self._compare(
            rng.random((30, pools)),
            rng.uniform(0.05, 0.95, pools),
            rng.integers(1, 8, pools),
        )

    def test_deferred_pool_skips_predictor(self):
        calls = []

        def pred(f):
            calls.append(float(f[0]))
            return float(f[0])

        ctl = AdmissionController(predictor=pred, horizon_cycles=3, threshold=0.5)
        assert not ctl.on_cycle(0, np.array([0.1]))  # risky -> defer through 3
        assert not ctl.on_cycle(1, np.array([0.9]))  # deferred: no predict
        assert calls == [0.1]

    def test_fleet_controller_with_batched_predictor(self):
        feats = np.array([[0.9, 0, 0], [0.1, 0, 0]])
        ctl = FleetAdmissionController(
            2, threshold=0.5, predictor=lambda x: x[:, 0]
        )
        np.testing.assert_array_equal(
            ctl.on_cycle(0, features=feats), [True, False]
        )
        with pytest.raises(ValueError):
            ctl.on_cycle(1)  # neither probs nor features

    def test_shape_mismatch_rejected(self):
        ctl = FleetAdmissionController(3)
        with pytest.raises(ValueError):
            ctl.on_cycle(0, np.zeros(4))

    def test_scalar_field_edits_are_honored(self):
        """The dataclass fields are public — post-construction edits must
        reach the decision (live-read behavior, as before the fleet-view
        refactor)."""
        ctl = AdmissionController(
            predictor=lambda f: float(f[0]), horizon_cycles=5, threshold=0.9
        )
        assert ctl.on_cycle(0, np.array([0.5]))      # 1-p=0.5 < 0.9: admit
        ctl.threshold = 0.3
        ctl.horizon_cycles = 2
        assert not ctl.on_cycle(1, np.array([0.5]))  # now risky -> defer
        assert not ctl.on_cycle(3, np.array([0.9]))  # deferred through 1+2
        assert ctl.on_cycle(4, np.array([0.9]))


class TestServeLauncher:
    def test_serve_fleet_smoke(self, capsys):
        """`python -m repro.launch.serve --spot-pools N` path at tiny
        shapes: the launcher drives the stream + fleet controller."""
        from repro.launch.serve import serve_fleet

        out = serve_fleet(5, 0.5, engine="fleet", seed=3)
        assert out["pools"] == 5 and out["cycles"] == 10
        assert out["admitted"] + out["deferred"] == 50
        assert "decisions/sec" in capsys.readouterr().out


class TestMigrationPlanners:
    def test_scalar_tie_break_ignores_insertion_order(self):
        pred = lambda f: float(f[0])  # noqa: E731
        tied = {"b": np.array([0.5]), "a": np.array([0.5]), "c": np.array([0.1])}
        # ties break toward sorted(pool_id) order, however the dict was built
        assert plan_migration(tied, pred, current="c") == "a"
        reordered = {k: tied[k] for k in ("a", "c", "b")}
        assert plan_migration(reordered, pred, current="c") == "a"

    def test_scalar_no_move_cases(self):
        pred = lambda f: float(f[0])  # noqa: E731
        feats = {"a": np.array([0.1]), "b": np.array([0.9]), "c": np.array([0.5])}
        assert plan_migration(feats, pred, current="a") == "b"
        assert plan_migration(feats, pred, current="b") is None

    def test_batch_matches_scalar_rule(self):
        rng = np.random.default_rng(7)
        for _ in range(50):
            scores = rng.choice([0.1, 0.3, 0.5, 0.9], size=6)  # force ties
            feats = {f"p{i}": np.array([s]) for i, s in enumerate(scores)}
            pred = lambda f: float(f[0])  # noqa: E731
            for cur in range(6):
                want = plan_migration(feats, pred, current=f"p{cur}")
                got = plan_migration_batch(scores, cur)
                assert (want is None) == (got is None)
                if want is not None:
                    assert want == f"p{got}"

    def test_batch_vectorised_currents(self):
        scores = np.array([0.2, 0.9, 0.3])
        np.testing.assert_array_equal(
            plan_migration_batch(scores, np.array([0, 1, 2])), [1, -1, 1]
        )

    def test_batch_margin_blocks_marginal_moves(self):
        assert plan_migration_batch(np.array([0.5, 0.5 + 1e-12]), 0) is None
        assert plan_migration_batch(np.array([0.5, 0.6]), 0) == 1

    def test_batch_rejects_bad_scores(self):
        with pytest.raises(ValueError):
            plan_migration_batch(np.zeros((2, 2)), 0)
