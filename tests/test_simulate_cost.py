"""Trace-driven simulation (§VI-E) + cost model (§VI-B) + co-interrupts."""

import numpy as np
import pytest

from repro.core import (
    cost_report,
    fraction_within,
    proximities,
    replay,
    run_strategies,
    tpcds_profile,
)
from repro.core.provider import InterruptionEvent
from repro.core.workloads import (
    TPCDS_MAX_SECONDS,
    TPCDS_MIN_SECONDS,
    TPCDS_TOTAL_SECONDS,
)


class TestWorkload:
    def test_tpcds_profile_matches_published_stats(self):
        d = tpcds_profile()
        assert len(d) == 99
        assert d.min() == TPCDS_MIN_SECONDS
        assert d.max() == TPCDS_MAX_SECONDS
        assert abs(d.sum() - TPCDS_TOTAL_SECONDS) < 1.0


class TestReplay:
    def test_no_interruptions_no_loss(self):
        avail = np.ones(480, dtype=int)
        r = replay(avail, [100.0, 200.0, 50.0])
        assert r.lost_seconds == 0.0
        assert r.completed == 3

    def test_interruption_loses_running_progress(self):
        # one query of 400 s; pool drops at cycle 2 (t=360 s)
        avail = np.array([1, 1, 0, 1, 1, 1])
        r = replay(avail, [400.0], dt=180.0)
        assert r.lost_seconds == pytest.approx(360.0)
        assert r.completed == 1  # retried and finished

    def test_fully_unavailable_trace_completes_nothing(self):
        r = replay(np.zeros(10, dtype=int), [100.0])
        assert r.completed == 0
        assert r.lost_seconds == 0.0  # nothing ever started

    def test_predict_ar_defers_and_avoids_loss(self):
        # pool: up 5 cycles, down 5, up 10 — oracle predictor
        avail = np.concatenate([np.ones(5), np.zeros(5), np.ones(10)]).astype(int)

        def oracle(c):
            h = 2
            future = avail[c + 1 : c + 1 + h]
            return int(future.all())

        base = replay(avail, [400.0] * 3, strategy="always_run", dt=180.0)
        pred = replay(
            avail, [400.0] * 3, strategy="predict_ar",
            predictor=oracle, horizon_cycles=2, dt=180.0,
        )
        assert pred.lost_seconds < base.lost_seconds
        assert pred.idle_seconds > 0.0  # deferral shows up as idle time

    def test_sjf_orders_queue(self):
        avail = np.ones(3, dtype=int)
        r = replay(avail, [500.0, 10.0, 20.0], strategy="sjf", dt=180.0)
        assert r.completed == 3  # 10+20+500 fits into 540

    def test_run_strategies_averages_permutations(self):
        avail = (np.arange(100) % 7 != 0).astype(int)
        results = run_strategies(avail, tpcds_profile()[:20], n_permutations=3)
        names = {r.strategy for r in results}
        assert names == {"always_run", "sjf"}
        for r in results:
            assert r.total_queries == 20


class TestCost:
    def test_fig5_ordering_and_bands(self, small_campaign):
        rep = cost_report(small_campaign)
        # continuous >> periodic >> SnS (Fig. 5, log scale)
        assert rep.continuous > rep.periodic > rep.sns_total
        assert rep.sns_compute == 0.0
        # paper: 249.5x over continuous, 2.5x over periodic — same decade
        assert 50 < rep.continuous_over_sns < 2000
        assert rep.periodic_over_sns > 1.0
        assert rep.resolution_ratio == pytest.approx(600.0 / small_campaign.interval)


class TestCoInterrupt:
    def test_proximity_nearest_neighbour(self):
        events = [
            InterruptionEvent("p", 1, 0.0),
            InterruptionEvent("p", 2, 10.0),
            InterruptionEvent("p", 3, 500.0),
        ]
        gaps = np.sort(proximities(events))
        np.testing.assert_allclose(gaps, [10.0, 10.0, 490.0])

    def test_singleton_pools_excluded(self):
        events = [InterruptionEvent("a", 1, 0.0), InterruptionEvent("b", 2, 5.0)]
        assert proximities(events).size == 0

    def test_campaign_cointerrupt_band(self, small_campaign):
        """Fig. 3: >85% within 1 min, ~93% within 3 min (loose band here)."""
        f1 = fraction_within(small_campaign.interruptions, 60.0)
        f3 = fraction_within(small_campaign.interruptions, 180.0)
        assert f3 >= f1
        assert f1 > 0.6
        assert f3 > 0.8
