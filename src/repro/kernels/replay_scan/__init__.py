"""Lock-step §VI-E trace replay in scan form (see ``core.simulate``).

* ``ref``    — the ``lax.scan`` closed-form reference (fast CPU path);
* ``kernel`` — the chunked Pallas kernel (carry in VMEM scratch);
* ``ops``    — backend dispatch, ragged-shape padding, row sharding.
"""

from .ops import replay_scan_op, replay_sweep_op

__all__ = ["replay_scan_op", "replay_sweep_op"]
