"""Serving engine: batched prefill + decode with SnS-aware admission.

``generate`` is the plain engine (prefill once, decode N tokens).
``FleetAdmissionController`` applies the paper's Predict-AR policy
(§VI-E) to serving admission *at fleet scale*: consult the SnS predictor
each collection cycle; for every pool it forecasts will not stay
available over the horizon, *defer admitting new requests*
(drain-friendly) while letting in-flight decodes finish — the same
leave-running-work-undisturbed semantics as §VI-E, with the defer clocks
of the whole fleet held in ``(pools,)`` arrays and every cycle decided in
a constant number of vector ops.  ``AdmissionController`` is the
paper-faithful one-pool view over it.  ``plan_migration_batch`` /
``plan_migration`` pick the healthiest alternative pool by current SnS
scores (SpotServe-style proactive migration, reduced to its scheduling
decision) under one shared deterministic tie-break rule.

The controllers consume the per-cycle ``probs`` column of a
:class:`repro.core.pipeline.CampaignPipelineStream` view — the streaming
measure → featurize → predict → **decide** path (see
``examples/serve_spot.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.common import ModelConfig

__all__ = [
    "generate",
    "AdmissionController",
    "FleetAdmissionController",
    "plan_migration",
    "plan_migration_batch",
]


def generate(
    cfg: ModelConfig,
    params,
    batch: Dict,
    *,
    max_new_tokens: int = 16,
    mesh=None,
    data_axes=("data",),
    greedy: bool = True,
    seed: int = 0,
) -> jnp.ndarray:
    """Prefill + decode loop; returns (B, max_new_tokens) generated ids."""
    b, s = batch["tokens"].shape
    logits, cache = api.prefill(
        cfg, params, batch, mesh=mesh, data_axes=data_axes,
        max_seq=s + max_new_tokens,
    )
    key = jax.random.PRNGKey(seed)
    outs = []
    tok = None
    for i in range(max_new_tokens):
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        outs.append(tok)
        if i + 1 < max_new_tokens:
            logits, cache = api.decode_step(
                cfg, params, cache, tok, mesh=mesh, data_axes=data_axes
            )
    return jnp.stack(outs, axis=1)


class FleetAdmissionController:
    """Predict-AR admission for the whole fleet — one vector op per cycle.

    The fleet-scale form of the paper's Predict-AR policy: the per-pool
    defer clocks live in one ``(pools,)`` int64 array and each collection
    cycle is decided for every pool at once from the cycle's ``(pools,)``
    availability-probability column (e.g. the ``probs`` view of a
    :class:`repro.core.pipeline.CampaignPipelineStream` cycle — already
    the product of the pipeline's single batched ``predict_proba`` call).

    Decisions are **bit-identical** to running one scalar
    :class:`AdmissionController` per pool (``tests/test_serve_stream.py``
    asserts this property across seeds, thresholds and horizons):

    * a pool inside its defer window is never admitted and its predictor
      score is ignored (the scalar controller doesn't even call the
      predictor there);
    * otherwise, ``1 - p_stay >= threshold`` starts a new defer window of
      ``horizon_cycles`` cycles; healthy pools are admitted.

    ``threshold`` and ``horizon_cycles`` broadcast per pool, so a fleet
    can mix risk appetites without per-pool Python objects.
    """

    def __init__(
        self,
        pools: int,
        *,
        horizon_cycles: Union[int, np.ndarray] = 5,
        threshold: Union[float, np.ndarray] = 0.5,
        predictor: Optional[Callable[[np.ndarray], np.ndarray]] = None,
    ):
        self.pools = int(pools)
        # broadcast_to yields read-only views — materialize writable copies
        self.horizon_cycles = np.broadcast_to(
            np.asarray(horizon_cycles, np.int64), (self.pools,)
        ).copy()
        self.threshold = np.broadcast_to(
            np.asarray(threshold, np.float64), (self.pools,)
        ).copy()
        self.predictor = predictor
        #: last cycle index (inclusive) each pool stays deferred through
        self.defer_until = np.full(self.pools, -1, dtype=np.int64)

    def on_cycle(
        self,
        cycle: int,
        probs: Optional[np.ndarray] = None,
        *,
        features: Optional[np.ndarray] = None,
        staleness: Optional[np.ndarray] = None,
        max_staleness: int = 0,
    ) -> np.ndarray:
        """Decide the whole fleet for one cycle.

        Pass the cycle's ``(pools,)`` ``P(stays available)`` column, or a
        ``(pools, F)`` feature matrix to route through the controller's
        batched ``predictor``.  Returns a ``(pools,)`` bool mask: True
        where NEW requests may be admitted this cycle.

        ``staleness`` (optional ``(pools,)`` int — e.g. the pipeline's
        :attr:`~repro.core.pipeline.StreamCycleView.staleness` under
        faults) enables conservative degradation: pools whose features are
        more than ``max_staleness`` cycles stale are never admitted this
        cycle, regardless of their (carried-forward) score.  Defer clocks
        still advance normally, so a stale-but-risky pool serves its defer
        window like any other.
        """
        if probs is None:
            if features is None:
                raise ValueError("need probs or features")
            if self.predictor is None:
                raise ValueError("no predictor attached; pass probs")
            probs = self.predictor(features)
        probs = np.asarray(probs, dtype=np.float64)
        if probs.shape != (self.pools,):
            raise ValueError(f"probs shape {probs.shape} != ({self.pools},)")
        deferred = cycle <= self.defer_until
        risky = (1.0 - probs) >= self.threshold
        start = ~deferred & risky
        self.defer_until = np.where(
            start, cycle + self.horizon_cycles, self.defer_until
        )
        admit = ~deferred & ~risky
        if staleness is not None:
            stale = np.asarray(staleness, dtype=np.int64)
            if stale.shape != (self.pools,):
                raise ValueError(
                    f"staleness shape {stale.shape} != ({self.pools},)"
                )
            admit = admit & ~(stale > int(max_staleness))
        return admit


@dataclasses.dataclass
class AdmissionController:
    """Predict-AR for serving admission (one controller per pool) — a thin
    single-pool view over :class:`FleetAdmissionController`; the defer
    arithmetic lives only in the fleet controller.  Each call pays a
    small (length-1) numpy round-trip for that sharing: fine at per-pool
    object scale, but hot fleet loops should hold ONE fleet controller
    (`benchmarks/serve_throughput.py` measures the gap)."""

    predictor: Callable[[np.ndarray], float]   # features -> P(stays available)
    horizon_cycles: int = 5
    threshold: float = 0.5
    _defer_until: int = -1

    def __post_init__(self):
        self._fleet = FleetAdmissionController(
            1, horizon_cycles=self.horizon_cycles, threshold=self.threshold
        )
        self._fleet.defer_until[0] = self._defer_until

    def on_cycle(self, cycle: int, features: np.ndarray) -> bool:
        """Returns True if NEW requests may be admitted this cycle."""
        fleet = self._fleet
        # the dataclass fields are public and mutable — honor live edits
        # by writing them through to the fleet controller every cycle
        fleet.threshold[0] = self.threshold
        fleet.horizon_cycles[0] = self.horizon_cycles
        deferred = cycle <= fleet.defer_until[0]
        # a deferred pool's score is ignored — skip the predictor call
        p_stay = 0.0 if deferred else float(self.predictor(features))
        admit = bool(fleet.on_cycle(cycle, np.array([p_stay]))[0])
        self._defer_until = int(fleet.defer_until[0])
        return admit


# Migration tie-break rule, shared by both planners: the target is the
# highest-scoring pool, ties broken toward the FIRST pool in canonical
# order — index order for the columnar planner, sorted(pool_id) order for
# the dict planner.  np.argmax implements "first maximum" exactly.


def plan_migration_batch(
    scores: np.ndarray,
    current: Union[int, np.ndarray],
    *,
    margin: float = 1e-9,
):
    """Columnar migration planning over the whole fleet at once.

    Args:
      scores: ``(pools,)`` availability scores for every candidate pool
        (e.g. the ``probs`` column of a pipeline-stream cycle).
      current: the currently occupied pool index, or an ``(k,)`` int array
        of indices for ``k`` independent serving placements.
      margin: minimum score improvement that justifies a migration.

    Returns:
      For a scalar ``current``: the target pool index, or ``None`` when
      ``current`` is (within ``margin`` of) the best — same contract as
      :func:`plan_migration`.  For an array: an ``(k,)`` int64 array with
      ``-1`` meaning "stay put".

    The target is ``argmax(scores)`` with ties broken toward the lowest
    pool index — deterministic regardless of how the score vector was
    assembled, and the same rule :func:`plan_migration` applies over
    sorted pool ids.
    """
    scores = np.asarray(scores, dtype=np.float64)
    if scores.ndim != 1 or scores.size == 0:
        raise ValueError(f"scores must be a non-empty vector, got {scores.shape}")
    best = int(np.argmax(scores))  # first maximum: the shared tie-break
    cur = np.asarray(current)
    scalar = cur.ndim == 0
    cur_arr = np.atleast_1d(cur).astype(np.int64)
    move = (cur_arr != best) & (scores[best] > scores[cur_arr] + margin)
    targets = np.where(move, np.int64(best), np.int64(-1))
    if scalar:
        return int(targets[0]) if targets[0] >= 0 else None
    return targets


def plan_migration(
    pool_features: Dict[str, np.ndarray],
    predictor: Callable[[np.ndarray], float],
    *,
    current: str,
) -> Optional[str]:
    """Pick the best migration target when `current` looks unhealthy.

    Returns None if `current` still scores best (no migration).  Pools
    are scored in ``sorted(pool_id)`` order and ties break toward the
    first — the same explicit rule as :func:`plan_migration_batch`, so
    the outcome never depends on dict insertion order."""
    order = sorted(pool_features)
    scores = np.array(
        [float(predictor(pool_features[pid])) for pid in order]
    )
    target = plan_migration_batch(scores, order.index(current))
    return None if target is None else order[target]
