"""Fused goodput replay in scan form (see ``fleet.runner``).

* ``ref``    — the ``lax.scan`` closed-form reference with the policies
  axis fused into the carried state (fast CPU path);
* ``kernel`` — the chunked Pallas kernel (carry in VMEM scratch);
* ``ops``    — backend dispatch, padding, metric assembly.
"""

from .ops import goodput_sweep_op

__all__ = ["goodput_sweep_op"]
