"""Trace-driven simulation (§VI-E) + cost model (§VI-B) + co-interrupts."""

import numpy as np
import pytest

from repro.core import (
    cost_report,
    fraction_within,
    proximities,
    replay,
    replay_batch,
    run_fleet_strategies,
    run_strategies,
    tpcds_profile,
)
from repro.core.provider import InterruptionEvent
from repro.core.workloads import (
    TPCDS_MAX_SECONDS,
    TPCDS_MIN_SECONDS,
    TPCDS_N_QUERIES,
    TPCDS_TOTAL_SECONDS,
)


class TestWorkload:
    def test_tpcds_profile_matches_published_stats(self):
        d = tpcds_profile()
        assert len(d) == 99
        assert d.min() == TPCDS_MIN_SECONDS
        assert d.max() == TPCDS_MAX_SECONDS
        assert abs(d.sum() - TPCDS_TOTAL_SECONDS) < 1e-6

    @pytest.mark.parametrize("seed", range(32))
    def test_profile_invariants_hold_for_every_seed(self, seed):
        """Property: sum / min / max / count are exact, not approximate —
        the clip-then-rescale loop must converge without re-violating the
        clip bounds on its final iteration."""
        d = tpcds_profile(seed)
        assert len(d) == TPCDS_N_QUERIES
        assert d.min() == TPCDS_MIN_SECONDS
        assert d.max() == TPCDS_MAX_SECONDS
        assert (d >= TPCDS_MIN_SECONDS).all() and (d <= TPCDS_MAX_SECONDS).all()
        assert abs(d.sum() - TPCDS_TOTAL_SECONDS) < 1e-6, d.sum()


class TestReplay:
    def test_no_interruptions_no_loss(self):
        avail = np.ones(480, dtype=int)
        r = replay(avail, [100.0, 200.0, 50.0])
        assert r.lost_seconds == 0.0
        assert r.completed == 3

    def test_interruption_loses_running_progress(self):
        # one query of 400 s; pool drops at cycle 2 (t=360 s)
        avail = np.array([1, 1, 0, 1, 1, 1])
        r = replay(avail, [400.0], dt=180.0)
        assert r.lost_seconds == pytest.approx(360.0)
        assert r.completed == 1  # retried and finished

    def test_fully_unavailable_trace_completes_nothing(self):
        r = replay(np.zeros(10, dtype=int), [100.0])
        assert r.completed == 0
        assert r.lost_seconds == 0.0  # nothing ever started

    def test_predict_ar_defers_and_avoids_loss(self):
        # pool: up 5 cycles, down 5, up 10 — oracle prediction array
        avail = np.concatenate([np.ones(5), np.zeros(5), np.ones(10)]).astype(int)
        h = 2
        oracle = np.array(
            [int(avail[c + 1 : c + 1 + h].all()) for c in range(len(avail))]
        )

        base = replay(avail, [400.0] * 3, strategy="always_run", dt=180.0)
        pred = replay(
            avail, [400.0] * 3, strategy="predict_ar",
            predictions=oracle, horizon_cycles=2, dt=180.0,
        )
        assert pred.lost_seconds < base.lost_seconds
        assert pred.idle_seconds > 0.0  # deferral shows up as idle time

    def test_sjf_orders_queue(self):
        avail = np.ones(3, dtype=int)
        r = replay(avail, [500.0, 10.0, 20.0], strategy="sjf", dt=180.0)
        assert r.completed == 3  # 10+20+500 fits into 540

    def test_run_strategies_averages_permutations(self):
        avail = (np.arange(100) % 7 != 0).astype(int)
        results = run_strategies(avail, tpcds_profile()[:20], n_permutations=3)
        names = {r.strategy for r in results}
        assert names == {"always_run", "sjf"}
        for r in results:
            assert r.total_queries == 20


class TestReplayBatch:
    """The vectorized lock-step replay is bit-identical to the scalar
    reference, row by row, for every strategy."""

    @pytest.mark.parametrize("strategy", ["always_run", "sjf", "predict_ar"])
    def test_batch_matches_scalar_rows(self, strategy, rng):
        T, Q, B = 48, 7, 16
        avail = (rng.random((B, T)) > 0.25).astype(int)
        dur = rng.uniform(5.0, 700.0, size=(B, Q))
        pred = (rng.random((B, T)) > 0.3).astype(int)
        batch = replay_batch(
            avail, dur, strategy=strategy, predictions=pred, horizon_cycles=2
        )
        for b in range(B):
            r = replay(
                avail[b], dur[b], strategy=strategy,
                predictions=pred[b], horizon_cycles=2,
            )
            assert batch["lost_seconds"][b] == r.lost_seconds
            assert batch["idle_seconds"][b] == r.idle_seconds
            assert batch["completed"][b] == r.completed
            assert batch["makespan_seconds"][b] == r.makespan_seconds
            assert batch["total_queries"][b] == r.total_queries

    def test_broadcast_single_trace(self):
        avail = np.ones(6, dtype=int)
        batch = replay_batch(avail, np.array([[100.0, 50.0], [700.0, 600.0]]))
        assert batch["completed"].tolist() == [2, 1]

    def test_predict_ar_requires_predictions(self):
        with pytest.raises(ValueError):
            replay_batch(np.ones(4), [10.0], strategy="predict_ar")

    def test_fleet_strategies_one_shot(self, rng):
        """pools × permutations × strategies in three batched calls,
        matching per-pool run_strategies driven with the pool's seed."""
        pools, T = 3, 60
        avail = (rng.random((pools, T)) > 0.2).astype(int)
        pred = (rng.random((pools, T)) > 0.3).astype(int)
        dur = tpcds_profile()[:12]
        out = run_fleet_strategies(
            avail, dur, predictions=pred, horizon_cycles=2, n_permutations=2
        )
        assert set(out) == {"always_run", "sjf", "predict_ar"}
        for p in range(pools):
            expect = run_strategies(
                avail[p], dur, predictions=pred[p], horizon_cycles=2,
                n_permutations=2, seed=p,
            )
            for r in expect:
                got = out[r.strategy][p]
                assert got.lost_seconds == pytest.approx(r.lost_seconds)
                assert got.idle_seconds == pytest.approx(r.idle_seconds)
                assert got.completed == r.completed


class TestCost:
    def test_fig5_ordering_and_bands(self, small_campaign):
        rep = cost_report(small_campaign)
        # continuous >> periodic >> SnS (Fig. 5, log scale)
        assert rep.continuous > rep.periodic > rep.sns_total
        assert rep.sns_compute == 0.0
        # paper: 249.5x over continuous, 2.5x over periodic — same decade
        assert 50 < rep.continuous_over_sns < 2000
        assert rep.periodic_over_sns > 1.0
        assert rep.resolution_ratio == pytest.approx(600.0 / small_campaign.interval)


class TestCoInterrupt:
    def test_proximity_nearest_neighbour(self):
        events = [
            InterruptionEvent("p", 1, 0.0),
            InterruptionEvent("p", 2, 10.0),
            InterruptionEvent("p", 3, 500.0),
        ]
        gaps = np.sort(proximities(events))
        np.testing.assert_allclose(gaps, [10.0, 10.0, 490.0])

    def test_singleton_pools_excluded(self):
        events = [InterruptionEvent("a", 1, 0.0), InterruptionEvent("b", 2, 5.0)]
        assert proximities(events).size == 0

    def test_campaign_cointerrupt_band(self, small_campaign):
        """Fig. 3: >85% within 1 min, ~93% within 3 min (loose band here)."""
        f1 = fraction_within(small_campaign.interruptions, 60.0)
        f3 = fraction_within(small_campaign.interruptions, 180.0)
        assert f3 >= f1
        assert f1 > 0.6
        assert f3 > 0.8
