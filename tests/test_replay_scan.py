"""Scan-form replay: cross-engine parity, contract edges, event log.

The load-bearing property: scalar :func:`replay`, the numpy per-cycle
oracle, the ``lax.scan`` reference (unsharded or mesh-sharded over the
trace axis), and the chunked Pallas kernel all implement the same
closed-form replay contract and must agree **exactly** (atol=0) on all
five metrics, row by row, for every strategy.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import replay, replay_batch, run_fleet_strategies, tpcds_profile
from repro.core.simulate import STRATEGIES

METRICS = (
    "lost_seconds", "idle_seconds", "completed", "total_queries",
    "makespan_seconds",
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: fixed shape pool so the property test reuses jit caches across examples
SHAPES = ((5, 24, 6), (3, 37, 9), (4, 30, 21))


def _workload(shape, seed, *, lo=0.5, hi=700.0, p_up=0.75):
    b, t, q = shape
    rng = np.random.default_rng(seed)
    avail = (rng.random((b, t)) < p_up).astype(int)
    dur = rng.uniform(lo, hi, size=(b, q))
    # exact-boundary stress: durations that divide dt evenly hit the
    # completion epsilon and the mid-cycle makespan edge
    dur[:, : q // 3] = rng.choice([180.0, 90.0, 45.0, 360.0], size=(b, q // 3))
    pred = (rng.random((b, t)) > 0.3).astype(int)
    return avail, dur, pred


def _assert_batches_equal(a, b, msg=""):
    for k in METRICS:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{msg} {k}")


def _assert_matches_scalar(batch, avail, dur, pred, strategy, h, dt=180.0):
    for row in range(avail.shape[0]):
        r = replay(
            avail[row], dur[row], strategy=strategy, dt=dt,
            predictions=pred[row], horizon_cycles=h,
        )
        assert batch["lost_seconds"][row] == r.lost_seconds
        assert batch["idle_seconds"][row] == r.idle_seconds
        assert batch["completed"][row] == r.completed
        assert batch["total_queries"][row] == r.total_queries
        assert batch["makespan_seconds"][row] == r.makespan_seconds


class TestEngineParity:
    """numpy oracle == scan == kernel == scalar, bit for bit."""

    @given(
        shape=st.sampled_from(SHAPES),
        seed=st.integers(0, 10_000),
        strategy=st.sampled_from(STRATEGIES),
        h=st.sampled_from((1, 2, 5)),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_scalar_numpy_scan(self, shape, seed, strategy, h):
        avail, dur, pred = _workload(shape, seed)
        kw = dict(strategy=strategy, predictions=pred, horizon_cycles=h)
        oracle = replay_batch(avail, dur, engine="numpy", **kw)
        scan = replay_batch(avail, dur, engine="scan", **kw)
        _assert_batches_equal(oracle, scan, f"{strategy} seed={seed}")
        _assert_matches_scalar(oracle, avail, dur, pred, strategy, h)

    @pytest.mark.parametrize(
        "strategy,h",
        [("always_run", 1), ("sjf", 1), ("predict_ar", 1), ("predict_ar", 5)],
    )
    def test_triple_parity_fig9_workload(self, strategy, h):
        """Kernel == scan ref == scalar on the Fig-9 shape: the TPC-DS
        99-query profile over 24 h of 3-minute cycles."""
        t_cycles = 480
        pools = 6
        rng = np.random.default_rng(7)
        avail = (rng.random((pools, t_cycles)) > 0.15).astype(int)
        pred = (rng.random((pools, t_cycles)) > 0.3).astype(int)
        dur = np.stack([rng.permutation(tpcds_profile()) for _ in range(pools)])
        kw = dict(strategy=strategy, predictions=pred, horizon_cycles=h)
        oracle = replay_batch(avail, dur, engine="numpy", **kw)
        scan = replay_batch(avail, dur, engine="scan", **kw)
        kernel = replay_batch(avail, dur, engine="kernel", **kw)
        _assert_batches_equal(oracle, scan, f"scan {strategy}")
        _assert_batches_equal(oracle, kernel, f"kernel {strategy}")
        _assert_matches_scalar(oracle, avail, dur, pred, strategy, h)

    def test_kernel_ragged_padding(self):
        """True nonzero padding: B > block_b with B % block_b != 0 and
        T > chunk with T % chunk != 0 (ops clamps block_b/chunk to the
        input shape, so smaller cases pad nothing)."""
        avail, dur, pred = _workload((11, 150, 7), seed=3)
        kw = dict(strategy="predict_ar", predictions=pred, horizon_cycles=2)
        oracle = replay_batch(avail, dur, engine="numpy", **kw)
        kernel = replay_batch(avail, dur, engine="kernel", **kw)
        _assert_batches_equal(oracle, kernel, "ragged kernel")

    def test_kernel_padding_inert_for_midflight_query(self):
        """A query still running at trace end must stay 'neither lost nor
        complete' through the kernel's padded tail cycles (the padding is
        avail=0, which must not act as a real down-cycle)."""
        avail = np.ones((9, 150), dtype=int)
        dur = np.full((9, 1), 1e9)
        oracle = replay_batch(avail, dur, engine="numpy")
        kernel = replay_batch(avail, dur, engine="kernel")
        assert oracle["lost_seconds"].tolist() == [0.0] * 9
        _assert_batches_equal(oracle, kernel, "padded midflight")

    def test_burst_completions_overflow_window(self):
        """sjf with many sub-cycle queries: one cycle completes far more
        queries than the scan's prefix-count window — the overflow loop
        must extend it without losing exactness."""
        avail, dur, pred = _workload((4, 40, 48), seed=11, lo=0.5, hi=30.0)
        for strategy in ("sjf", "always_run"):
            kw = dict(strategy=strategy, predictions=pred, horizon_cycles=1)
            oracle = replay_batch(avail, dur, engine="numpy", **kw)
            scan = replay_batch(avail, dur, engine="scan", **kw)
            _assert_batches_equal(oracle, scan, f"burst {strategy}")


class TestFusedSweep:
    """The strategies-fused sweep vs S independent per-strategy runs, and
    the f32 fast tier vs the f64 oracle."""

    def _dyadic_workload(self, shape, seed, *, lo=0.5, hi=700.0):
        """Durations quantised to 1/32 s: every replay quantity is then
        exactly representable in float32 (sums × 32 stay ≪ 2^24), so the
        f32 tier must reproduce the f64 oracle bit for bit."""
        avail, dur, pred = _workload(shape, seed, lo=lo, hi=hi)
        return avail, np.round(dur * 32.0) / 32.0, pred

    @pytest.mark.parametrize("engine", ["scan", "kernel"])
    def test_fused_equals_per_strategy_all_engines(self, engine):
        from repro.core import replay_sweep

        avail, dur, pred = _workload((5, 60, 9), seed=2)
        fused = replay_sweep(avail, dur, strategies=STRATEGIES,
                             predictions=pred, horizon_cycles=2,
                             engine=engine)
        for s in STRATEGIES:
            for per_engine in ("numpy", "scan"):
                per = replay_batch(avail, dur, strategy=s, predictions=pred,
                                   horizon_cycles=2, engine=per_engine)
                _assert_batches_equal(fused[s], per,
                                      f"fused[{engine}] vs {per_engine} {s}")

    @given(shape=st.sampled_from(SHAPES), seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_property_f32_identical_on_dyadic(self, shape, seed):
        from repro.core import replay_sweep

        avail, dur, pred = self._dyadic_workload(shape, seed)
        kw = dict(strategies=STRATEGIES, predictions=pred, horizon_cycles=2,
                  engine="scan")
        f64 = replay_sweep(avail, dur, precision="f64", **kw)
        f32 = replay_sweep(avail, dur, precision="f32", **kw)
        for s in STRATEGIES:
            # integer decisions identical always; floats identical too on
            # the dyadic workload (nothing rounds in either tier)
            _assert_batches_equal(f64[s], f32[s], f"f32 tier {s}")

    def test_f32_identical_through_burst_overflow(self):
        """Sub-cycle sjf bursts overflow the prefix-count window in both
        tiers; the overflow loop must preserve the f32 identity."""
        from repro.core import replay_sweep

        avail, dur, pred = self._dyadic_workload((4, 40, 48), seed=11,
                                                 lo=0.5, hi=30.0)
        kw = dict(strategies=STRATEGIES, predictions=pred, horizon_cycles=1,
                  engine="scan")
        f64 = replay_sweep(avail, dur, precision="f64", **kw)
        f32 = replay_sweep(avail, dur, precision="f32", **kw)
        for s in STRATEGIES:
            _assert_batches_equal(f64[s], f32[s], f"burst f32 {s}")

    def test_f32_identical_on_ragged_kernel_padding(self):
        """f32 through the Pallas kernel path with real row/cycle padding
        (B % block_b != 0, T % chunk != 0)."""
        from repro.core import replay_sweep

        avail, dur, pred = self._dyadic_workload((11, 150, 7), seed=3)
        kw = dict(strategies=STRATEGIES, predictions=pred, horizon_cycles=2,
                  engine="kernel")
        f64 = replay_sweep(avail, dur, precision="f64", **kw)
        f32 = replay_sweep(avail, dur, precision="f32", **kw)
        for s in STRATEGIES:
            _assert_batches_equal(f64[s], f32[s], f"ragged kernel f32 {s}")

    def test_f32_rejected_outside_supported_engines(self):
        from repro.core import replay_sweep

        with pytest.raises(ValueError, match="precision"):
            replay_sweep(np.ones((2, 4), dtype=int), np.full((2, 3), 90.0),
                         strategies=("always_run",), precision="f16")


class TestContractEdges:
    def test_mid_cycle_makespan(self):
        # 2 queries totalling 250 s finish mid-way through cycle 1
        r = replay(np.ones(4, dtype=int), [100.0, 150.0], dt=180.0)
        assert r.completed == 2
        assert r.makespan_seconds == pytest.approx(250.0)
        batch = replay_batch(np.ones(4, dtype=int), [100.0, 150.0], engine="scan")
        assert batch["makespan_seconds"][0] == r.makespan_seconds

    def test_makespan_exact_cycle_boundary(self):
        # the last query consumes exactly the full cycle budget
        r = replay(np.ones(3, dtype=int), [180.0], dt=180.0)
        assert r.completed == 1
        assert r.makespan_seconds == pytest.approx(180.0)

    def test_requeued_query_is_retried_in_full(self):
        # 400 s query interrupted at 360 s of progress loses all of it
        avail = np.array([1, 1, 0, 1, 1, 1])
        r = replay(avail, [400.0], dt=180.0)
        assert r.lost_seconds == pytest.approx(360.0)
        assert r.completed == 1

    def test_predict_ar_deferral_accrues_idle(self):
        avail = np.ones(10, dtype=int)
        pred = np.zeros(10, dtype=int)      # always forecasts trouble
        r = replay(
            avail, [100.0], strategy="predict_ar",
            predictions=pred, horizon_cycles=100,
        )
        # the single query never launches; every cycle is idle
        assert r.completed == 0
        assert r.idle_seconds == pytest.approx(10 * 180.0)

    def test_empty_queue_all_idle(self):
        for engine in ("numpy", "scan"):
            batch = replay_batch(
                np.ones((2, 5), dtype=int), np.zeros((2, 0)), engine=engine
            )
            np.testing.assert_allclose(batch["idle_seconds"], 5 * 180.0)
            assert batch["completed"].tolist() == [0, 0]

    def test_unknown_engine_raises(self):
        with pytest.raises(ValueError):
            replay_batch(np.ones(4), [10.0], engine="cuda")

    def test_fleet_strategies_identical_across_engines(self):
        """The fig9 identity: run_fleet_strategies through the scan path
        produces exactly the SimResults of the numpy path."""
        pools, t_cycles = 3, 60
        rng = np.random.default_rng(5)
        avail = (rng.random((pools, t_cycles)) > 0.2).astype(int)
        pred = (rng.random((pools, t_cycles)) > 0.3).astype(int)
        dur = tpcds_profile()[:12]
        a = run_fleet_strategies(
            avail, dur, predictions=pred, horizon_cycles=2,
            n_permutations=2, engine="numpy",
        )
        b = run_fleet_strategies(
            avail, dur, predictions=pred, horizon_cycles=2,
            n_permutations=2, engine="scan",
        )
        assert set(a) == set(b)
        for s in a:
            for ra, rb in zip(a[s], b[s]):
                assert ra == rb


class TestInterruptionLog:
    def test_lazy_view_and_columns(self):
        from repro.core import InterruptionEvent, InterruptionLog

        log = InterruptionLog(["a/r/1", "b/r/1"])
        log.append_sweep(1, [4, 5], [10.0, 11.5])
        log.append_sweep(0, [0], [99.0])
        assert len(log) == 3
        assert log[0] == InterruptionEvent("b/r/1", 4, 10.0)
        assert log[-1] == InterruptionEvent("a/r/1", 0, 99.0)
        assert list(log) == log[:]
        pool, uid, time = log.columns
        assert pool.tolist() == [1, 1, 0]
        assert uid.tolist() == [4, 5, 0]
        assert time.tolist() == [10.0, 11.5, 99.0]
        snap = log.snapshot()
        assert snap == log
        assert snap == list(log)
        log.append_sweep(0, [9], [120.0])
        assert len(snap) == 3          # snapshot is frozen
        assert snap != log

    def test_columnar_proximities_match_dict_path(self, small_campaign):
        from repro.core import proximities

        log = small_campaign.interruptions
        fast = np.sort(proximities(log))
        slow = np.sort(proximities(list(log)))
        np.testing.assert_allclose(fast, slow)


class TestMeshShardedReplay:
    """The trace-axis ``shard_map`` path of the scan backend.

    Single-device runs only ever see ``n_shards == 1`` (the plain scan),
    so real mesh coverage needs virtual devices — the XLA host-platform
    flag must be set before jax first initialises, hence the subprocess.
    The invariant under test: sharding the trace axis is invisible —
    every metric bit-identical to both the unsharded scan and the numpy
    per-cycle oracle, including ragged shard sizes (inert-row padding)
    and the B < shards clamp.
    """

    def test_shards_one_is_plain_scan(self):
        avail, dur, pred = _workload((5, 24, 6), seed=2)
        kw = dict(strategy="predict_ar", predictions=pred, horizon_cycles=2)
        a = replay_batch(avail, dur, engine="scan", **kw)
        b = replay_batch(avail, dur, engine="scan", shards=1, **kw)
        _assert_batches_equal(a, b, "shards=1")

    def test_shards_exceeding_devices_raises(self):
        avail, dur, pred = _workload((4, 20, 5), seed=4)
        with pytest.raises(ValueError, match="visible"):
            replay_batch(
                avail, dur, engine="scan", shards=4096,
                predictions=pred, horizon_cycles=1,
            )

    def test_shards_invalid_raises(self):
        avail, dur, _ = _workload((4, 20, 5), seed=4)
        with pytest.raises(ValueError, match=">= 1"):
            replay_batch(avail, dur, engine="scan", shards=0)

    def test_four_way_mesh_parity(self):
        """4-virtual-device subprocess: mesh-sharded scan == unsharded
        scan == numpy oracle, bit for bit, on ragged (13 rows over 4
        shards), B < shards (2 rows), and evenly divisible shapes."""
        code = textwrap.dedent(
            """
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import numpy as np
            import jax
            assert len(jax.devices()) == 4, jax.devices()

            from repro.core import replay_batch

            def workload(shape, seed):
                b, t, q = shape
                rng = np.random.default_rng(seed)
                avail = (rng.random((b, t)) < 0.75).astype(int)
                dur = rng.uniform(0.5, 700.0, size=(b, q))
                dur[:, : q // 3] = rng.choice(
                    [180.0, 90.0, 45.0, 360.0], size=(b, q // 3))
                pred = (rng.random((b, t)) > 0.3).astype(int)
                return avail, dur, pred

            METRICS = ("lost_seconds", "idle_seconds", "completed",
                       "total_queries", "makespan_seconds")
            # (rows, cycles, queries): ragged 13 % 4 != 0, B < shards,
            # and an even split
            for shape, strategy, h in (
                ((13, 50, 9), "predict_ar", 2),
                ((2, 30, 4), "sjf", 1),
                ((64, 200, 17), "always_run", 1),
            ):
                avail, dur, pred = workload(shape, seed=sum(shape))
                kw = dict(strategy=strategy, predictions=pred,
                          horizon_cycles=h)
                oracle = replay_batch(avail, dur, engine="numpy", **kw)
                plain = replay_batch(avail, dur, engine="scan",
                                     shards=1, **kw)
                auto = replay_batch(avail, dur, engine="scan", **kw)
                pinned = replay_batch(avail, dur, engine="scan",
                                      shards=4, **kw)
                for got, tag in ((plain, "plain"), (auto, "auto"),
                                 (pinned, "shards=4")):
                    for k in METRICS:
                        np.testing.assert_array_equal(
                            oracle[k], got[k],
                            err_msg=f"{shape} {strategy} {tag} {k}")
            print("MESH_REPLAY_OK")
            """
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, "-c", code],
            capture_output=True, text=True, timeout=900, env=env,
        )
        assert proc.returncode == 0, proc.stderr
        assert "MESH_REPLAY_OK" in proc.stdout
