"""Vectorised retry/backoff control plane for campaign collection.

One ``RetryController`` instance fronts a whole fleet: every operation
is a single ``(pools,)`` array op per cycle, matching the serve layer's
defer-clock idiom (``FleetAdmissionController``).  Three mechanisms
compose:

* **Capped exponential backoff** — after each whole-call control-plane
  fault a pool's next attempt is pushed out by
  ``min(base * 2**(streak-1), max)`` cycles plus a *deterministic*
  jitter drawn from the SplitMix64 stream ``(policy.seed, pool,
  cycle)``, so scalar/fleet/sharded engines compute identical
  schedules.
* **Per-region token bucket** — ``attempt_mask`` optionally pre-gates
  attempts against the provider's live rate budget (the same budget
  ``_charge_rate_limit_batch`` enforces), admitting the first
  ``budget // n_requests`` eligible pools per region in pool order so
  the limiter itself never has to refuse a call.
* **Per-pool circuit breaker** — ``breaker_threshold`` consecutive
  faults open the breaker; after ``breaker_cooldown_cycles`` it goes
  half-open and admits a single probe cycle, closing on success and
  re-opening on fault.

Pools suppressed by the controller surface as ``OUTCOME_DEFERRED``
cycles (no API charge) and masked observations downstream.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .faults import BILLED_FAULT_CODES
from .rng import keyed_uniform

# Breaker states (int8).
BREAKER_CLOSED = 0
BREAKER_OPEN = 1
BREAKER_HALF_OPEN = 2

# RNG tag for backoff jitter — disjoint from provider (< 30M) and
# fault (30M–31M) tag ranges.
_TAG_RETRY_JITTER = 32_000_000


@dataclass(frozen=True)
class RetryPolicy:
    """Deterministic backoff/breaker policy shared by all engines."""

    seed: int = 0
    base_delay_cycles: int = 1
    max_delay_cycles: int = 8
    jitter: float = 0.5
    breaker_threshold: int = 4
    breaker_cooldown_cycles: int = 6

    def __post_init__(self) -> None:
        if self.base_delay_cycles < 1:
            raise ValueError("base_delay_cycles must be >= 1")
        if self.max_delay_cycles < self.base_delay_cycles:
            raise ValueError("max_delay_cycles must be >= base_delay_cycles")
        if not 0.0 <= float(self.jitter) <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        if self.breaker_cooldown_cycles < 1:
            raise ValueError("breaker_cooldown_cycles must be >= 1")


def base_backoff(policy: RetryPolicy, streaks):
    """Un-jittered backoff (cycles) — monotone in streak, capped at max.

    ``streaks`` counts *consecutive* faults (>= 1).  Exposed separately
    so the monotonicity/cap properties are directly testable.
    """
    streaks = np.asarray(streaks, dtype=np.int64)
    exp = np.clip(streaks - 1, 0, 32)
    raw = np.left_shift(np.int64(policy.base_delay_cycles), exp)
    return np.minimum(raw, np.int64(policy.max_delay_cycles))


def backoff_delays(policy: RetryPolicy, streaks, pool_idx, cycle):
    """Backoff + deterministic jitter for pools that just faulted.

    The jitter term is ``floor(u * (jitter * delay + 1))`` with
    ``u = keyed_uniform(policy.seed, pool, cycle, jitter_tag)`` — pure
    in its inputs, so identical across engines, and strictly below
    ``jitter * delay + 1`` so the effective delay stays within
    ``[delay, delay * (1 + jitter) + 1)``.
    """
    delay = base_backoff(policy, streaks)
    u = keyed_uniform(
        policy.seed, np.asarray(pool_idx, dtype=np.int64), int(cycle), _TAG_RETRY_JITTER
    )
    extra = np.floor(u * (policy.jitter * delay + 1.0)).astype(np.int64)
    return delay + extra


class RetryController:
    """Per-pool retry clocks + circuit breakers as flat arrays."""

    def __init__(self, n_pools, policy=None, *, region_code=None, n_requests=1):
        self.pools = int(n_pools)
        self.policy = policy if policy is not None else RetryPolicy()
        self.fail_streak = np.zeros(self.pools, dtype=np.int64)
        self.retry_at = np.zeros(self.pools, dtype=np.int64)
        self.breaker = np.zeros(self.pools, dtype=np.int8)
        self.opened_at = np.full(self.pools, -1, dtype=np.int64)
        self._region_code = (
            None if region_code is None else np.asarray(region_code, dtype=np.int64)
        )
        self._n = int(n_requests)

    # -- per-cycle API -------------------------------------------------

    def attempt_mask(self, cycle, *, region_budget=None):
        """(pools,) bool — which pools may call the API this cycle.

        Transitions OPEN breakers whose cooldown elapsed to HALF_OPEN
        (their single probe attempt).  When ``region_budget`` (an array
        of remaining calls per region code) is given, attempts are
        token-bucket pre-gated: only the first ``budget // n_requests``
        eligible pools per region (in pool order) attempt, mirroring
        ``_charge_rate_limit_batch``'s admission order exactly.
        """
        cycle = int(cycle)
        pol = self.policy
        due_half = (self.breaker == BREAKER_OPEN) & (
            cycle >= self.opened_at + pol.breaker_cooldown_cycles
        )
        self.breaker[due_half] = BREAKER_HALF_OPEN
        mask = (cycle >= self.retry_at) & (self.breaker != BREAKER_OPEN)
        if region_budget is not None and self._region_code is not None:
            budget = np.asarray(region_budget, dtype=np.int64)
            for rc in np.unique(self._region_code):
                sel = np.nonzero(mask & (self._region_code == rc))[0]
                cap = max(0, int(budget[rc]) // max(self._n, 1))
                if sel.size > cap:
                    mask[sel[cap:]] = False
        return mask

    def observe(self, cycle, attempted, codes):
        """Fold one cycle's outcome codes into clocks and breakers.

        ``attempted`` is the mask returned by :meth:`attempt_mask` (or a
        subset); ``codes`` the per-pool ``OUTCOME_*`` codes.  Only
        whole-call control-plane faults (throttle/timeout/blackout)
        count against the breaker — capacity rejections and per-request
        errors are data, not control-plane failures.
        """
        cycle = int(cycle)
        attempted = np.asarray(attempted, dtype=bool)
        codes = np.asarray(codes, dtype=np.uint8)
        faulted = attempted & np.isin(codes, np.array(BILLED_FAULT_CODES, np.uint8))
        ok = attempted & ~faulted

        self.fail_streak[ok] = 0
        self.retry_at[ok] = cycle + 1
        self.breaker[ok & (self.breaker == BREAKER_HALF_OPEN)] = BREAKER_CLOSED

        if faulted.any():
            self.fail_streak[faulted] += 1
            idx = np.nonzero(faulted)[0]
            delays = backoff_delays(self.policy, self.fail_streak[idx], idx, cycle)
            self.retry_at[idx] = cycle + delays
            reopen = faulted & (self.breaker == BREAKER_HALF_OPEN)
            trip = (
                faulted
                & (self.breaker == BREAKER_CLOSED)
                & (self.fail_streak >= self.policy.breaker_threshold)
            )
            tripped = reopen | trip
            self.breaker[tripped] = BREAKER_OPEN
            self.opened_at[tripped] = cycle

    # -- checkpointing -------------------------------------------------

    def state_dict(self):
        return {
            "fail_streak": self.fail_streak.copy(),
            "retry_at": self.retry_at.copy(),
            "breaker": self.breaker.copy(),
            "opened_at": self.opened_at.copy(),
        }

    def restore(self, sd):
        self.fail_streak[:] = sd["fail_streak"]
        self.retry_at[:] = sd["retry_at"]
        self.breaker[:] = sd["breaker"]
        self.opened_at[:] = sd["opened_at"]


__all__ = [
    "BREAKER_CLOSED",
    "BREAKER_OPEN",
    "BREAKER_HALF_OPEN",
    "RetryPolicy",
    "RetryController",
    "base_backoff",
    "backoff_delays",
]
