"""Data Pipeline — paper §V, Fig. 4 (middle module), at two scales.

Connects the Data Lake to the Interrupt Predictor.  Two implementations
share the same cycle contract (ingest one collection cycle's success
counts, update SR/UR/CUT incrementally in O(1) per pool, attach the
predictor's output to the stored record):

* **Per-pool objects** (:class:`FeatureProcessor` / :class:`WindowTable` /
  :class:`DataArchive`) — the paper-faithful reference: a Python dict of
  per-pool streaming states, one ``PredictFn`` call per pool per cycle.
  Exact, readable, and fine at the paper's 68 pools.

* **Fleet-vectorised** (:class:`FleetFeatureProcessor` /
  :class:`FleetWindowTable`) — the SpotLake-class scale-up (instance
  types × regions × AZs ≈ 10⁴–10⁶ pools): all per-pool state lives in
  stacked arrays (``repro.core.features.update_batch``), one cycle is a
  constant number of vector ops regardless of fleet size, and the
  predictor is invoked **once per cycle on the full (pools, features)
  batch** instead of once per pool.  The window table is a set of ring
  arrays — no per-row Python objects — bounded by the window length,
  with evictions counted into a stacked archive.  Outputs are
  bit-identical to the per-pool path (``tests/test_fleet_pipeline.py``).

For offline bulk replay of long traces at this scale use the chunked
streaming kernel (``repro.kernels.sns_features``) which carries the same
per-pool state across time-chunks in VMEM; this module is the *online*
(cycle-at-a-time) form of the same computation.

The O(1) claim is tested by counting state-update work per cycle for both
paths (``tests/test_pipeline.py``, ``tests/test_fleet_pipeline.py``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .features import (
    FeatureState,
    FleetFeatureState,
    init_fleet_state,
    init_state,
    update,
    update_batch,
)

__all__ = [
    "WindowRow",
    "WindowTable",
    "DataArchive",
    "FeatureProcessor",
    "FleetCycleResult",
    "FleetWindowTable",
    "FleetFeatureProcessor",
    "StreamCycleView",
    "CampaignPipelineStream",
    "run_campaign_pipeline",
]

PredictFn = Callable[[np.ndarray], float]
#: fleet-scale predictor: one (pools, n_features) batch -> (pools,) scores
BatchPredictFn = Callable[[np.ndarray], np.ndarray]


@dataclasses.dataclass
class WindowRow:
    cycle: int
    time: float
    s_t: int
    features: Tuple[float, float, float]
    prediction: Optional[float] = None


class DataArchive:
    """Cold storage for rows evicted from the window table."""

    def __init__(self):
        self._rows: Dict[str, List[WindowRow]] = {}

    def archive(self, pool_id: str, row: WindowRow) -> None:
        self._rows.setdefault(pool_id, []).append(row)

    def rows(self, pool_id: str) -> List[WindowRow]:
        return self._rows.get(pool_id, [])

    def __len__(self) -> int:
        return sum(len(v) for v in self._rows.values())


class WindowTable:
    """Recent rows + feature state per pool; bounded by the window length."""

    def __init__(self, archive: Optional[DataArchive] = None):
        self.rows: Dict[str, Deque[WindowRow]] = {}
        self.state: Dict[str, FeatureState] = {}
        self.archive = archive or DataArchive()

    def append(self, pool_id: str, row: WindowRow, max_rows: int) -> None:
        dq = self.rows.setdefault(pool_id, deque())
        dq.append(row)
        while len(dq) > max_rows:
            self.archive.archive(pool_id, dq.popleft())

    def latest(self, pool_id: str) -> Optional[WindowRow]:
        dq = self.rows.get(pool_id)
        return dq[-1] if dq else None


class FeatureProcessor:
    """Incremental feature computation + prediction fan-out (§V).

    The per-pool reference implementation: exact, O(1) per pool per cycle,
    but with Python-interpreter work linear in the fleet size.  Use
    :class:`FleetFeatureProcessor` past a few hundred pools.
    """

    def __init__(
        self,
        pool_ids: Sequence[str],
        *,
        n_requests: int = 10,
        window_minutes: float = 480.0,
        dt_minutes: float = 3.0,
        predict_fn: Optional[PredictFn] = None,
    ):
        self.pool_ids = list(pool_ids)
        self.n = n_requests
        self.dt_minutes = dt_minutes
        self.window_cycles = int(round(window_minutes / dt_minutes))
        self.table = WindowTable()
        self.predict_fn = predict_fn
        for pid in self.pool_ids:
            self.table.state[pid] = init_state(n_requests, window_minutes, dt_minutes)
        # instrumentation for the O(1)-per-update test
        self.update_ops = 0

    def on_cycle(self, cycle: int, time: float, s: Sequence[int]) -> Dict[str, WindowRow]:
        """Ingest one collection cycle's success counts for all pools."""
        if len(s) != len(self.pool_ids):
            raise ValueError("per-pool success counts length mismatch")
        out: Dict[str, WindowRow] = {}
        for pid, s_t in zip(self.pool_ids, s):
            state = self.table.state[pid]
            state, feats = update(state, int(s_t))
            self.update_ops += 1  # one O(1) state update per pool per cycle
            row = WindowRow(cycle=cycle, time=time, s_t=int(s_t), features=feats)
            if self.predict_fn is not None:
                row.prediction = float(self.predict_fn(np.asarray(feats)))
            self.table.append(pid, row, max_rows=self.window_cycles)
            out[pid] = row
        return out

    def feature_matrix(self, pool_id: str) -> np.ndarray:
        """(rows, 3) matrix of in-window features for one pool."""
        return np.asarray([r.features for r in self.table.rows.get(pool_id, [])])


# --------------------------------------------------------------------------
# Fleet-vectorised pipeline
# --------------------------------------------------------------------------


@dataclasses.dataclass
class FleetCycleResult:
    """One cycle's outputs for the whole fleet (stacked, not per-row)."""

    cycle: int
    time: float
    s_t: np.ndarray                      # (pools,) int
    features: np.ndarray                 # (pools, 3) float64 — (SR, UR, CUT)
    predictions: Optional[np.ndarray]    # (pools,) float or None
    #: (pools,) int64 — consecutive invalid (faulted/deferred) cycles per
    #: pool; None when the cycle ran without a validity mask
    staleness: Optional[np.ndarray] = None


class FleetWindowTable:
    """Window Table as stacked ring arrays — no per-row Python objects.

    Holds the last ``window_cycles`` cycles of success counts, features,
    and attached predictions for every pool; rows falling out of the
    window are *counted* into the archive (cold storage at fleet scale is
    a bulk store, not per-row objects — keep ``archive_evicted=True`` to
    retain the evicted feature blocks for offline dataset builds).
    """

    def __init__(
        self,
        pools: int,
        window_cycles: int,
        *,
        n_features: int = 3,
        archive_evicted: bool = False,
    ):
        w = int(window_cycles)
        self.pools = pools
        self.window_cycles = w
        self.s = np.zeros((pools, w), dtype=np.int64)
        self.features = np.zeros((pools, w, n_features), dtype=np.float64)
        self.predictions = np.full((pools, w), np.nan)
        self.cycles = np.full(w, -1, dtype=np.int64)   # slot -> cycle id
        self.times = np.zeros(w)
        self.head = -1          # ring slot of the latest cycle
        self.count = 0          # filled slots (<= window_cycles)
        self.archived_cycles = 0
        self.archive_evicted = archive_evicted
        self._archive_blocks: List[np.ndarray] = []    # evicted (pools, F) rows

    def append_cycle(
        self,
        cycle: int,
        time: float,
        s_t: np.ndarray,
        features: np.ndarray,
        predictions: Optional[np.ndarray] = None,
    ) -> None:
        self.head = (self.head + 1) % self.window_cycles
        if self.count == self.window_cycles:
            self.archived_cycles += 1
            if self.archive_evicted:
                self._archive_blocks.append(self.features[:, self.head].copy())
        else:
            self.count += 1
        self.s[:, self.head] = s_t
        self.features[:, self.head] = features
        self.predictions[:, self.head] = (
            np.nan if predictions is None else predictions
        )
        self.cycles[self.head] = cycle
        self.times[self.head] = time

    @property
    def archived(self) -> int:
        """Evicted rows across the fleet (pools × evicted cycles)."""
        return self.archived_cycles * self.pools

    @property
    def nbytes(self) -> int:
        """Host bytes held by the ring buffers plus any archived blocks.

        With ``archive_evicted=False`` (the streaming-serve default) this
        is flat in cycles — bounded by ``pools × window_cycles`` — which
        the bounded-memory tests assert; archived blocks grow with the
        campaign by design."""
        ring = (
            self.s.nbytes + self.features.nbytes + self.predictions.nbytes
            + self.cycles.nbytes + self.times.nbytes
        )
        return ring + sum(b.nbytes for b in self._archive_blocks)

    def _order(self) -> np.ndarray:
        """Ring slots in chronological order (oldest -> newest)."""
        w, c = self.window_cycles, self.count
        return (np.arange(self.head - c + 1, self.head + 1)) % w

    def feature_matrix(self, pool_index: int) -> np.ndarray:
        """(rows, F) in-window features for one pool, oldest first."""
        return self.features[pool_index, self._order()]

    def trailing(self, length: int) -> np.ndarray:
        """(pools, length, F) most recent feature sequences (for sequence
        models); requires at least ``length`` ingested cycles."""
        if self.count < length:
            raise ValueError(f"only {self.count} cycles in window, need {length}")
        return self.features[:, self._order()[-length:]]

    def state_dict(self) -> dict:
        """Snapshot the ring arrays + archive for crash-consistent
        checkpointing (plain numpy/python values, picklable)."""
        return {
            "s": self.s.copy(),
            "features": self.features.copy(),
            "predictions": self.predictions.copy(),
            "cycles": self.cycles.copy(),
            "times": self.times.copy(),
            "head": self.head,
            "count": self.count,
            "archived_cycles": self.archived_cycles,
            "archive_blocks": [b.copy() for b in self._archive_blocks],
        }

    def restore(self, sd: dict) -> None:
        """Inverse of :meth:`state_dict` onto an identically-configured
        table."""
        self.s[:] = sd["s"]
        self.features[:] = sd["features"]
        self.predictions[:] = sd["predictions"]
        self.cycles[:] = sd["cycles"]
        self.times[:] = sd["times"]
        self.head = int(sd["head"])
        self.count = int(sd["count"])
        self.archived_cycles = int(sd["archived_cycles"])
        self._archive_blocks = [np.asarray(b).copy() for b in sd["archive_blocks"]]

    def latest(self) -> FleetCycleResult:
        if self.count == 0:
            raise ValueError("window table is empty")
        h = self.head
        preds = self.predictions[:, h]
        # copies, not views: a held result must stay stable after the ring
        # wraps and overwrites the slot
        return FleetCycleResult(
            cycle=int(self.cycles[h]),
            time=float(self.times[h]),
            s_t=self.s[:, h].copy(),
            features=self.features[:, h].copy(),
            predictions=None if np.isnan(preds).all() else preds.copy(),
        )


class FleetFeatureProcessor:
    """Fleet-vectorised incremental features + one batched prediction/cycle.

    Per cycle: one :func:`~repro.core.features.update_batch` call (a
    constant number of vector ops over stacked state — the fleet-scale
    form of Algorithm 1's O(1) update) and, when a predictor is attached,
    exactly one ``predict_fn`` call on the full ``(pools, features)``
    matrix (see ``repro.core.predictor.batched_predict_fn``).  With
    ``sequence_length=L`` the predictor instead receives the fleet's
    trailing-window tensor ``(pools, L, features)`` — the sequence-model
    serving path (lstm/transformer); predictions stay ``None`` until L
    cycles of history exist.

    Feature outputs are bit-identical to :class:`FeatureProcessor`;
    interpreter work per cycle is O(1) in the fleet size.
    """

    def __init__(
        self,
        pools: Union[int, Sequence[str]],
        *,
        n_requests: int = 10,
        window_minutes: float = 480.0,
        dt_minutes: float = 3.0,
        predict_fn: Optional[BatchPredictFn] = None,
        sequence_length: Optional[int] = None,
        archive_evicted: bool = False,
    ):
        if isinstance(pools, int):
            self.pool_ids = [f"pool{i}" for i in range(pools)]
        else:
            self.pool_ids = list(pools)
        self.pool_index = {pid: i for i, pid in enumerate(self.pool_ids)}
        self.n = n_requests
        self.dt_minutes = dt_minutes
        self.state: FleetFeatureState = init_fleet_state(
            len(self.pool_ids), n_requests, window_minutes, dt_minutes
        )
        self.window_cycles = self.state.w  # the one validated derivation
        self.table = FleetWindowTable(
            len(self.pool_ids), self.window_cycles,
            archive_evicted=archive_evicted,
        )
        self.predict_fn = predict_fn
        if sequence_length is not None and not 1 <= sequence_length <= self.window_cycles:
            raise ValueError(
                f"sequence_length {sequence_length} outside [1, window_cycles"
                f"={self.window_cycles}]"
            )
        self.sequence_length = sequence_length
        # instrumentation for the O(1)-work-per-cycle tests:
        self.update_ops = 0     # batched state updates (1 per cycle)
        self.predict_calls = 0  # predictor invocations (<= 1 per cycle)

    def on_cycle(
        self,
        cycle: int,
        time: float,
        s: Sequence[int],
        valid: Optional[np.ndarray] = None,
    ) -> FleetCycleResult:
        """Ingest one collection cycle's success-count vector for the fleet.

        ``valid`` (optional ``(pools,)`` bool) marks live measurements —
        invalid pools (faulted / throttled / retry-deferred cycles) carry
        their last features forward and accrue staleness (see
        :func:`~repro.core.features.update_batch`).
        """
        s_t = np.array(s)  # copy: the result must not alias a caller buffer
        self.state, feats = update_batch(self.state, s_t, valid)
        self.update_ops += 1  # one batched O(pools)-element / O(1)-op update

        # Commit the row before predicting: a failing predictor then leaves
        # state and table in sync (predictions just stay None), so a caller
        # that catches the error and moves on never double-applies this S_t.
        self.table.append_cycle(cycle, time, s_t, feats, None)

        preds = None
        if self.predict_fn is not None:
            if self.sequence_length is None:
                x = feats
            elif self.table.count >= self.sequence_length:
                x = self.table.trailing(self.sequence_length)
            else:
                x = None  # sequence history still filling
            if x is not None:
                preds = np.asarray(self.predict_fn(x), dtype=np.float64)
                self.predict_calls += 1
                if preds.shape != (len(self.pool_ids),):
                    raise ValueError(
                        f"predict_fn returned shape {preds.shape}, "
                        f"expected ({len(self.pool_ids)},)"
                    )
                self.table.predictions[:, self.table.head] = preds
        return FleetCycleResult(
            cycle=cycle, time=time, s_t=s_t, features=feats, predictions=preds,
            staleness=None if valid is None else self.state.staleness.copy(),
        )

    def feature_matrix(self, pool_id: Union[str, int]) -> np.ndarray:
        """(rows, 3) in-window features for one pool, oldest first."""
        idx = pool_id if isinstance(pool_id, int) else self.pool_index[pool_id]
        return self.table.feature_matrix(idx)

    def state_dict(self) -> dict:
        """Snapshot the stacked Algorithm-1 state + window table (plain
        numpy/python values) for crash-consistent checkpointing."""
        st = self.state
        return {
            "t": st.t,
            "p_t": st.p_t.copy(),
            "cut": np.asarray(st.cut).copy(),
            "p_window": st.p_window.copy(),
            "head": st.head,
            "staleness": st.staleness.copy(),
            "last_feats": np.asarray(st.last_feats).copy(),
            "table": self.table.state_dict(),
            "update_ops": self.update_ops,
            "predict_calls": self.predict_calls,
        }

    def restore(self, sd: dict) -> None:
        """Inverse of :meth:`state_dict` onto an identically-configured
        processor (same pools / n / window / dt / predictor)."""
        st = self.state
        st.t = int(sd["t"])
        st.p_t[:] = sd["p_t"]
        st.cut = np.asarray(sd["cut"]).copy()
        st.p_window[:] = sd["p_window"]
        st.head = int(sd["head"])
        st.staleness = np.asarray(sd["staleness"]).copy()
        st.last_feats = np.asarray(sd["last_feats"]).copy()
        self.table.restore(sd["table"])
        self.update_ops = int(sd["update_ops"])
        self.predict_calls = int(sd["predict_calls"])


# --------------------------------------------------------------------------
# Campaign → pipeline glue (streaming serve path)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class StreamCycleView:
    """One cycle of the streaming serve path — zero-copy, read-only views.

    ``s_t`` / ``running_t`` are column views into the campaign stream's
    preallocated matrices (stable for the stream's lifetime);
    ``features`` / ``probs`` are slot views into the
    :class:`FleetWindowTable` ring arrays, valid **until the ring wraps**
    (``window_cycles`` cycles later) — copy them if you hold a view across
    more than a window of cycles.  All four are marked non-writeable
    (they alias live pipeline state — copy to scribble).  ``probs`` is
    ``None`` when no predictor is attached or a sequence model's history
    is still filling.
    """

    cycle: int
    time: float
    s_t: np.ndarray                  # (pools,) int64 — SnS success counts
    running_t: np.ndarray            # (pools,) int64 — ground-truth nodes
    features: np.ndarray             # (pools, F) float64 — (SR, UR, CUT)
    probs: Optional[np.ndarray]      # (pools,) float64 — P(stays available)
    #: (pools,) int64 — consecutive invalid cycles per pool (graceful
    #: degradation under faults); None when the campaign runs fault-free
    staleness: Optional[np.ndarray] = None


class CampaignPipelineStream:
    """Resumable measure → featurize → predict stream (§V, online form).

    The cycle-at-a-time refactor of :func:`run_campaign_pipeline`: wraps a
    :class:`~repro.core.collector.CampaignStream` (any engine —
    ``fleet`` / ``scalar`` / ``sharded``) and a
    :class:`FleetFeatureProcessor`, so each :meth:`step` runs exactly one
    collection cycle, one batched ``update_batch``, and at most one
    batched ``predict_fn`` call for the whole fleet, then hands back a
    :class:`StreamCycleView` of ``(S_t, features, probs)`` over the
    preallocated campaign matrices and window-table ring arrays.

    This is the serving glue point: feed ``view.probs`` to
    :class:`repro.serve.FleetAdmissionController` /
    :func:`repro.serve.plan_migration_batch` for per-cycle admission and
    migration decisions, ``view`` to
    :class:`repro.core.dataset.DatasetStreamer` to grow training data
    live, or wrap the whole stream in
    :class:`repro.fleet.runner.GoodputStream` to turn the per-cycle
    probabilities into live checkpoint/panic decisions for elastic
    training.  Features, predictions and the final :meth:`result` are
    bit-identical to the batch driver (:func:`run_campaign_pipeline`), by
    construction: the batch driver just drains this stream.
    """

    def __init__(
        self,
        provider,
        *,
        processor: Optional[FleetFeatureProcessor] = None,
        predict_fn: Optional[BatchPredictFn] = None,
        window_minutes: float = 480.0,
        sequence_length: Optional[int] = None,
        **campaign_kwargs,
    ):
        from .collector import CampaignStream  # local: avoid import cycle

        pool_ids = campaign_kwargs.pop("pool_ids", None)
        pool_ids = list(pool_ids) if pool_ids is not None else provider.pool_ids
        n_requests = campaign_kwargs.pop("n_requests", 10)
        interval = campaign_kwargs.get("interval", 180.0)
        if processor is None:
            processor = FleetFeatureProcessor(
                pool_ids,
                n_requests=n_requests,
                window_minutes=window_minutes,
                dt_minutes=interval / 60.0,
                predict_fn=predict_fn,
                sequence_length=sequence_length,
            )
        self.processor = processor
        self.campaign = CampaignStream(
            provider,
            pool_ids=pool_ids,
            n_requests=n_requests,
            **campaign_kwargs,
        )

    @property
    def n_cycles(self) -> int:
        return self.campaign.n_cycles

    @property
    def pools(self) -> int:
        return len(self.processor.pool_ids)

    @property
    def done(self) -> bool:
        return self.campaign.done

    @property
    def host_buffer_nbytes(self) -> int:
        """Bytes held by the window-table ring (see
        :meth:`FleetWindowTable.nbytes`) — the stream-side piece of the
        bounded-memory contract.  The campaign matrices themselves are
        preallocated at ``pools × cycles`` (they are the output)."""
        return self.processor.table.nbytes

    def step(self) -> Optional[StreamCycleView]:
        """Run one cycle end to end (measure → featurize → predict);
        ``None`` once the campaign is over."""
        cyc = self.campaign.step()
        if cyc is None:
            return None
        res = self.processor.on_cycle(cyc.cycle, cyc.time, cyc.s_t, cyc.valid_t)
        table = self.processor.table
        head = table.head
        features = table.features[:, head]
        features.flags.writeable = False  # aliases the ring — copy to scribble
        probs = None
        if res.predictions is not None:
            probs = table.predictions[:, head]
            probs.flags.writeable = False
        return StreamCycleView(
            cycle=cyc.cycle,
            time=cyc.time,
            s_t=cyc.s_t,
            running_t=cyc.running_t,
            features=features,
            probs=probs,
            staleness=res.staleness,
        )

    def __iter__(self):
        while True:
            view = self.step()
            if view is None:
                return
            yield view

    def state_dict(self) -> dict:
        """Crash-consistent snapshot of the whole measure → featurize →
        predict stream: the campaign engine state (provider ledgers, RNG
        cursors, retry/breaker state — see
        :meth:`CampaignStream.state_dict`) plus the pipeline's feature
        state and window table.  Restoring onto a freshly-constructed,
        identically-configured stream and draining it reproduces the
        uninterrupted run bit-identically."""
        return {
            "campaign": self.campaign.state_dict(),
            "processor": self.processor.state_dict(),
        }

    def restore(self, sd: dict) -> None:
        """Inverse of :meth:`state_dict`; see there."""
        self.campaign.restore(sd["campaign"])
        self.processor.restore(sd["processor"])

    def result(self):
        """The finished campaign's ``CampaignResult`` (requires all
        cycles consumed — see :meth:`CampaignStream.result`)."""
        return self.campaign.result()

    def run(self):
        """Drain remaining cycles; returns ``(result, processor)`` exactly
        like :func:`run_campaign_pipeline`."""
        for _ in self:
            pass
        return self.result(), self.processor


def run_campaign_pipeline(
    provider,
    *,
    processor: Optional[FleetFeatureProcessor] = None,
    predict_fn: Optional[BatchPredictFn] = None,
    window_minutes: float = 480.0,
    sequence_length: Optional[int] = None,
    **campaign_kwargs,
):
    """Stream a measurement campaign straight into the batched pipeline.

    Runs the whole campaign through a :class:`CampaignPipelineStream`
    (fleet engine by default) and feeds every collection cycle's
    success-count vector into a :class:`FleetFeatureProcessor` as it
    lands: one batched ``update_batch`` and at most **one** ``predict_fn``
    call per cycle for the whole fleet — the measure → featurize → predict
    loop of §V with no per-pool Python work between the layers.  For
    cycle-at-a-time consumption (serving admission, dataset streaming) use
    :class:`CampaignPipelineStream` directly; this batch driver just
    drains one.

    Campaign options (including ``engine``) pass through via
    ``campaign_kwargs``: with ``engine="sharded"`` the cycle's ``S_t``
    lands from the device-sharded admission step and flows into the same
    ``update_batch`` + ``batched_predict_fn`` path — features and
    predictions stay bit-identical to the fleet engine
    (``tests/test_sharded_campaign.py``).

    Pass an existing ``processor`` to keep accumulating into it, or let
    one be built from the campaign's pool list and cadence.  Returns
    ``(CampaignResult, FleetFeatureProcessor)``.
    """
    return CampaignPipelineStream(
        provider,
        processor=processor,
        predict_fn=predict_fn,
        window_minutes=window_minutes,
        sequence_length=sequence_length,
        **campaign_kwargs,
    ).run()
