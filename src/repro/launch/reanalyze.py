"""Recompute roofline analyses from saved HLO dumps (no recompilation).

The byte model in roofline.py evolves during §Perf iteration; this tool
re-derives `analysis` + `roofline` for every dry-run JSON whose HLO text
was dumped, keeping the table consistent with the current model.

  PYTHONPATH=src python -m repro.launch.reanalyze --out results/dryrun --hlo results/hlo
"""

import argparse
import glob
import json
import os

from repro.launch.roofline import analyze_hlo, roofline_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo", default="results/hlo")
    args = ap.parse_args()

    n = 0
    for path in sorted(glob.glob(os.path.join(args.out, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        hlo_path = os.path.join(
            args.hlo, f"{cell['arch']}__{cell['shape']}__{cell['mesh']}.hlo.txt"
        )
        if not os.path.exists(hlo_path):
            print(f"[miss] {hlo_path}")
            continue
        with open(hlo_path) as f:
            text = f.read()
        analysis = analyze_hlo(text, total_devices=cell["devices"])
        cell["analysis"] = {k: float(v) for k, v in analysis.items()}
        cell["roofline"] = roofline_report(
            analysis, model_flops_per_device=cell["model_flops_per_device"]
        )
        with open(path, "w") as f:
            json.dump(cell, f, indent=1)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
