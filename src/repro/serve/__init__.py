from .engine import AdmissionController, generate, plan_migration

__all__ = ["AdmissionController", "generate", "plan_migration"]
